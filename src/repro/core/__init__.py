"""Reference-Oriented Storage (ROS) — the paper's core contribution.

Public API:

    from repro.core import ReferenceServer, TensorHubClient

    server = ReferenceServer()
    hub = TensorHubClient(server)
    handle = hub.open("actor", "trainer-0", num_shards=W, shard_idx=R,
                      retain="latest")
    handle.register(named_tensors)
    handle.publish(version=0)
    ...

Fault tolerance (controller crash recovery):

    from repro.core import OpLog, recover, take_snapshot

    log = OpLog()
    server = ReferenceServer(log=log)          # every mutation is logged
    ...                                        # controller dies
    standby = recover(log)                     # bit-identical replay
    hub.failover(standby)                      # clients resume in place
"""

from repro.core.client import ShardHandle, TensorHubClient
from repro.core.errors import (
    ChecksumError,
    ConsistencyError,
    MutabilityViolationError,
    NotRegisteredError,
    ServerUnavailableError,
    ShardLayoutError,
    StaleHandleError,
    TensorHubError,
    VersionUnavailableError,
)
from repro.core.failover import (
    recover,
    state_digest,
    take_snapshot,
)
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.oplog import OpLog, OpRecord, Snapshot
from repro.core.server import (
    Assignment,
    Event,
    ReferenceServer,
    UpdateDecision,
    UnpublishResult,
    offload_name,
)

__all__ = [
    "Assignment",
    "ChecksumError",
    "ConsistencyError",
    "Event",
    "MutabilityViolationError",
    "NotRegisteredError",
    "OpLog",
    "OpRecord",
    "ReferenceServer",
    "ServerUnavailableError",
    "ShardHandle",
    "ShardLayoutError",
    "ShardManifest",
    "Snapshot",
    "StaleHandleError",
    "TensorHubClient",
    "TensorHubError",
    "TensorMeta",
    "TransferUnit",
    "UnpublishResult",
    "UpdateDecision",
    "VersionUnavailableError",
    "WorkerInfo",
    "offload_name",
    "recover",
    "state_digest",
    "take_snapshot",
]
