"""Reference-Oriented Storage (ROS) — the paper's core contribution.

Public API:

    from repro.core import ReferenceServer, TensorHubClient

    server = ReferenceServer()
    hub = TensorHubClient(server)
    handle = hub.open("actor", "trainer-0", num_shards=W, shard_idx=R,
                      retain="latest")
    handle.register(named_tensors)
    handle.publish(version=0)
    ...
"""

from repro.core.client import ShardHandle, TensorHubClient
from repro.core.errors import (
    ChecksumError,
    ConsistencyError,
    MutabilityViolationError,
    NotRegisteredError,
    ShardLayoutError,
    StaleHandleError,
    TensorHubError,
    VersionUnavailableError,
)
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.server import (
    Assignment,
    Event,
    ReferenceServer,
    UpdateDecision,
    UnpublishResult,
    offload_name,
)

__all__ = [
    "Assignment",
    "ChecksumError",
    "ConsistencyError",
    "Event",
    "MutabilityViolationError",
    "NotRegisteredError",
    "ReferenceServer",
    "ShardHandle",
    "ShardLayoutError",
    "ShardManifest",
    "StaleHandleError",
    "TensorHubClient",
    "TensorHubError",
    "TensorMeta",
    "TransferUnit",
    "UnpublishResult",
    "UpdateDecision",
    "VersionUnavailableError",
    "WorkerInfo",
    "offload_name",
]
