"""Version algebra: absolute integers and relative "latest[-k]" specs (4.1).

Each model evolves over integer *versions*, one per training step. RL cares
about freshness relative to the newest weights, so TensorHub resolves the
strings "latest" and "latest-k" against the model's current latest version.
Off-by-k-step algorithms (AReaL, Laminar, LlamaRL, ...) address co-existing
versions with "latest-k".
"""

from __future__ import annotations

import re
from typing import Optional, Union

VersionSpec = Union[int, str]

_RELATIVE_RE = re.compile(r"^latest(?:-(\d+))?$")


def is_relative(spec: VersionSpec) -> bool:
    return isinstance(spec, str)


def parse_relative(spec: str) -> int:
    """Return the lag k for a relative spec ("latest" -> 0, "latest-3" -> 3)."""
    m = _RELATIVE_RE.match(spec)
    if not m:
        raise ValueError(
            f"bad version spec {spec!r}: expected an int, 'latest', or 'latest-k'"
        )
    return int(m.group(1) or 0)


def resolve(spec: VersionSpec, latest: Optional[int]) -> Optional[int]:
    """Resolve a version spec against the model's latest version.

    Returns None when the spec cannot be satisfied yet (no version published,
    or the lag reaches before version history started).
    """
    if isinstance(spec, int):
        if spec < 0:
            raise ValueError(f"absolute version must be >= 0, got {spec}")
        return spec
    lag = parse_relative(spec)
    if latest is None:
        return None
    v = latest - lag
    return v if v >= 0 else None
