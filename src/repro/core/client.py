"""TensorHub client library: the Table-2 API (4.2).

``TensorHubClient`` is the per-process endpoint; ``ShardHandle`` is the
per-shard handle returned by :func:`TensorHubClient.open`. This is the
*real* (threaded, blocking) implementation used by tests and the RL
examples; the benchmark harness drives the same server through the
discrete-event simulator instead (``repro.transfer.simcluster``).

Blocking semantics are layered on the non-blocking server: a
``threading.Condition`` guards every server call, and the server's watcher
hook wakes waiters after each state mutation.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Mapping, Optional

import numpy as np

from repro.core import server as server_lib
from repro.core.errors import (
    StaleHandleError,
    TensorHubError,
    VersionUnavailableError,
)
from repro.core.meta import WorkerInfo
from repro.core.server import Assignment, ReferenceServer, offload_name
from repro.transfer.engine import (
    LocalTransport,
    TransportError,
    WorkerRegistry,
    WorkerStore,
)

_POLL = 0.02  # condition re-check period (seconds)


def dtype_from_str(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registers bfloat16 etc.

        return np.dtype(getattr(ml_dtypes, name))


class TensorHubClient:
    """Process-wide client endpoint: server + transport + registry."""

    def __init__(
        self,
        server: ReferenceServer,
        *,
        registry: Optional[WorkerRegistry] = None,
        transport: Optional[LocalTransport] = None,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.server = server
        self.registry = registry or WorkerRegistry()
        self.transport = transport or LocalTransport(self.registry)
        self.clock = clock
        self._cv = threading.Condition(threading.RLock())
        server.add_watcher(self._wake)

    def _wake(self) -> None:
        # The watcher fires while the server mutation holds our lock (all
        # server calls go through `self._cv`). Out-of-band mutations (test
        # harnesses injecting failures) are tolerated: waiters re-poll on
        # their own timeout.
        try:
            self._cv.notify_all()
        except RuntimeError:
            pass

    def open(
        self,
        model_name: str,
        replica_name: str,
        num_shards: int,
        shard_idx: int,
        *,
        retain: Optional[object] = None,
        datacenter: str = "dc0",
        node: Optional[str] = None,
        is_spot: bool = False,
        offload_seeding: bool = False,
        with_checksums: bool = True,
    ) -> "ShardHandle":
        worker = WorkerInfo(
            worker_id=f"{replica_name}/shard{shard_idx}",
            node=node or f"{datacenter}/{replica_name}",
            datacenter=datacenter,
            is_spot=is_spot,
        )
        with self._cv:
            self.server.open(
                model_name,
                replica_name,
                num_shards,
                shard_idx,
                worker=worker,
                retain=retain,
            )
        return ShardHandle(
            client=self,
            model=model_name,
            replica=replica_name,
            shard_idx=shard_idx,
            num_shards=num_shards,
            worker=worker,
            offload_seeding=offload_seeding,
            with_checksums=with_checksums,
        )


class ShardHandle:
    """Handle for one shard of one replica (Table 2)."""

    def __init__(
        self,
        *,
        client: TensorHubClient,
        model: str,
        replica: str,
        shard_idx: int,
        num_shards: int,
        worker: WorkerInfo,
        offload_seeding: bool,
        with_checksums: bool,
    ) -> None:
        self.client = client
        self.model = model
        self.replica = replica
        self.shard_idx = shard_idx
        self.num_shards = num_shards
        self.worker = worker
        self.offload_seeding = offload_seeding
        self.with_checksums = with_checksums
        self.store = WorkerStore(worker.worker_id)
        self.current_version: Optional[int] = None
        self._op_seq = 0
        self._off_op_seq = 1_000_000  # twin namespace, disjoint from main ops
        self._offload_stores: Dict[int, WorkerStore] = {}
        self._seed_threads: Dict[int, threading.Thread] = {}
        self._closed = False

    # -- helpers ---------------------------------------------------------------

    @property
    def _cv(self) -> threading.Condition:
        return self.client._cv

    @property
    def _server(self) -> ReferenceServer:
        return self.client.server

    def _next_op(self) -> int:
        op = self._op_seq
        self._op_seq += 1
        return op

    def _next_off_op(self) -> int:
        op = self._off_op_seq
        self._off_op_seq += 1
        return op

    # -- Table 2: register / unregister -----------------------------------------

    def register(self, named_tensors: Mapping[str, np.ndarray]) -> None:
        self.store.register(named_tensors)
        self.client.registry.add(self.replica, self.shard_idx, self.store)
        with self._cv:
            self._server.register(self.model, self.replica, self.shard_idx)

    def unregister(self) -> None:
        with self._cv:
            self._server.unregister(self.model, self.replica, self.shard_idx)
        self.client.registry.remove(self.replica, self.shard_idx)
        self.store.unregister()

    # -- Table 2: publish / unpublish --------------------------------------------

    def publish(self, version: int) -> None:
        manifest = self.store.build_manifest(with_checksums=self.with_checksums)
        op = self._next_op()
        with self._cv:
            self._server.publish(
                self.model, self.replica, self.shard_idx, version, manifest, op_id=op
            )
        self.current_version = version

    def unpublish(self) -> None:
        op = self._next_op()
        with self._cv:
            res = self._server.unpublish(
                self.model, self.replica, self.shard_idx, op_id=op
            )
        if res.offload_required:
            assert res.offload_version is not None
            self._do_retention_offload(res.offload_version)
        self._wait_drained()
        self.current_version = None
        self.process_events()

    def _do_retention_offload(self, version: int) -> None:
        """Retention protocol (3.3): copy this shard to host memory and
        publish the copy before the GPU buffers may be reused."""
        off_store = WorkerStore(f"{self.worker.worker_id}@offload")
        self.store.snapshot_to(off_store)
        self._offload_stores[version] = off_store
        self.client.registry.add(offload_name(self.replica), self.shard_idx, off_store)
        manifest = off_store.build_manifest(with_checksums=self.with_checksums)
        op = self._next_op()
        with self._cv:
            self._server.publish_offload(
                self.model, self.replica, self.shard_idx, version, manifest, op_id=op
            )

    def _wait_drained(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._server.finish_unpublish(self.model, self.replica):
                if deadline is not None and time.monotonic() > deadline:
                    raise TensorHubError(f"{self.replica}: drain timed out")
                self._cv.wait(_POLL)

    # -- Table 2: replicate / update ----------------------------------------------

    def replicate(self, version: object = "latest", *, timeout: Optional[float] = None) -> int:
        """Materialize ``version`` into the registered tensors; blocks until
        the version exists. Returns the absolute version fetched."""
        op = self._next_op()
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            assignment = self._server.begin_replicate(
                self.model, self.replica, self.shard_idx, version, op_id=op
            )
            while assignment is None:
                if deadline is not None and time.monotonic() > deadline:
                    raise VersionUnavailableError(
                        f"{self.model} {version!r}: not published within timeout"
                    )
                self._cv.wait(_POLL)
                assignment = self._server.redeem(self.model, self.replica, op_id=op)
        self._pull(assignment, op_id=op, dest_name=self.replica, dest_store=self.store)
        self.current_version = assignment.version
        self.process_events()
        return assignment.version

    def update(self, version: object = "latest") -> bool:
        """Atomically switch to a newer version if available (Table 2)."""
        op = self._next_op()
        with self._cv:
            d = self._server.begin_update(
                self.model,
                self.replica,
                self.shard_idx,
                version,
                op_id=op,
                offload_seeding=self.offload_seeding,
            )
        if d.seed_started and d.seed_version is not None:
            self._spawn_seed_pull(d.seed_version)
        if not d.updated:
            self.process_events()
            return False
        if d.offload_required and d.offload_version is not None:
            self._do_retention_offload(d.offload_version)
        self._wait_drained()
        assert d.assignment is not None
        self._pull(d.assignment, op_id=op, dest_name=self.replica, dest_store=self.store)
        self.current_version = d.version
        self.process_events()
        return True

    # -- Table 2: list / wait / close ------------------------------------------------

    def list(self) -> Dict[int, set]:
        with self._cv:
            return self._server.list_versions(self.model)

    def wait(self, predicate: Callable[[Dict[int, set]], bool], *, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not predicate(self._server.list_versions(self.model)):
                if deadline is not None and time.monotonic() > deadline:
                    raise TensorHubError("wait(): predicate not satisfied within timeout")
                self._cv.wait(_POLL)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for t in self._seed_threads.values():
            t.join(timeout=5.0)
        try:
            if self.current_version is not None:
                self.unpublish()
        except (StaleHandleError, TensorHubError):
            pass
        with self._cv:
            self._server.close(self.model, self.replica, self.shard_idx)
        self.client.registry.remove(self.replica, self.shard_idx)
        self.client.registry.remove(offload_name(self.replica), self.shard_idx)

    # -- housekeeping -----------------------------------------------------------------

    def heartbeat(self, now: Optional[float] = None) -> None:
        with self._cv:
            self._server.heartbeat(
                self.model, self.replica, self.shard_idx,
                self.client.clock() if now is None else now,
            )

    def process_events(self) -> None:
        """Drain server events: free released offload buffers (3.3)."""
        with self._cv:
            events = self._server.poll_events(self.worker.worker_id)
        for ev in events:
            if ev.kind == "offload_release" and ev.version is not None:
                store = self._offload_stores.pop(ev.version, None)
                if store is not None:
                    store.unregister()
                if not self._offload_stores:
                    self.client.registry.remove(offload_name(self.replica), self.shard_idx)

    # -- data plane ---------------------------------------------------------------------

    def _wait_manifest(self, version: int):
        with self._cv:
            while True:
                m = self._server.manifest(self.model, version, self.shard_idx)
                if m is not None:
                    return m
                self._cv.wait(_POLL)

    def _pull(
        self,
        assignment: Assignment,
        *,
        op_id: int,
        dest_name: str,
        dest_store: WorkerStore,
        twin: bool = False,
    ) -> None:
        """The replication loop (4.3.3): repeatedly read the source's
        progress counter, fetch the available prefix of transfer units,
        advance our own counter; re-route on source failure (4.5).

        ``complete_replicate`` gets its *own* op id, allocated here — the
        allocation point is the same in every shard's program order (SPMD),
        so the group op keys stay aligned without ever reusing the begin
        op's id (whose transaction may still be open on slow shards).
        """
        del op_id  # the begin op id; completion uses a fresh one (below)
        version = assignment.version
        manifest = self._wait_manifest(version)
        units = manifest.units
        source = assignment.source
        done = 0
        while done < len(units):
            # wait for the source to have at least one more unit than us
            avail = -1
            with self._cv:
                while True:
                    try:
                        avail = self._server.shard_progress(
                            self.model, source, version, self.shard_idx
                        )
                    except (StaleHandleError, TensorHubError):
                        avail = -1
                        break
                    if avail > done:
                        break
                    self._cv.wait(_POLL)
            if avail < 0:
                source = self._handle_source_failure(dest_name, source)
                continue
            failed = False
            for i in range(done, avail):
                try:
                    self.client.transport.pull_unit(
                        source, self.shard_idx, units[i], manifest.checksums[i], dest_store
                    )
                except TransportError:
                    source = self._handle_source_failure(dest_name, source)
                    failed = True
                    break
                done += 1
                with self._cv:
                    self._server.update_progress(
                        self.model, dest_name, self.shard_idx, version, done
                    )
            if failed:
                continue
        complete_op = self._next_off_op() if twin else self._next_op()
        with self._cv:
            self._server.complete_replicate(
                self.model, dest_name, self.shard_idx, version, op_id=complete_op
            )

    def _handle_source_failure(self, dest_name: str, dead_source: str) -> str:
        """Report a dead source and wait for the server to re-route us."""
        with self._cv:
            self._server.report_transfer_failure(self.model, dest_name, dead_source)
            while True:
                new = self._server.get_assignment(self.model, dest_name)
                if new is not None:
                    return new.source
                self._cv.wait(_POLL)

    # -- offload seeding (4.3.4) -----------------------------------------------------------

    def _spawn_seed_pull(self, version: int) -> None:
        if version in self._seed_threads:
            return
        t = threading.Thread(
            target=self._seed_pull, args=(version,), daemon=True,
            name=f"{self.worker.worker_id}-seed-v{version}",
        )
        self._seed_threads[version] = t
        t.start()

    def _seed_pull(self, version: int) -> None:
        """Background cross-DC fetch into a CPU buffer; the accelerator keeps
        computing and a later update() consumes the completed seed locally."""
        twin = offload_name(self.replica)
        manifest = self._wait_manifest(version)
        buffers = {
            t.name: np.zeros(t.shape, dtype=dtype_from_str(t.dtype))
            for t in manifest.tensors
        }
        off_store = WorkerStore(f"{self.worker.worker_id}@seed")
        off_store.register(buffers)
        self._offload_stores[version] = off_store
        self.client.registry.add(twin, self.shard_idx, off_store)
        with self._cv:
            assignment = None
            while assignment is None:
                assignment = self._server.get_assignment(self.model, twin)
                if assignment is None:
                    self._cv.wait(_POLL)
        self._pull(
            assignment,
            op_id=self._next_off_op(),
            dest_name=twin,
            dest_store=off_store,
            twin=True,
        )
