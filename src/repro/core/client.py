"""TensorHub client library: the Table-2 API (4.2).

``TensorHubClient`` is the per-process endpoint; ``ShardHandle`` is the
per-shard handle returned by :func:`TensorHubClient.open`. This is the
*real* (threaded, blocking) implementation used by tests and the RL
examples; the benchmark harness drives the same server through the
discrete-event simulator instead (``repro.transfer.simcluster``).

Blocking semantics are layered on the non-blocking server: a
``threading.Condition`` guards every server call, and the server's watcher
hook wakes waiters after each state mutation.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Dict, List, Mapping, Optional, Set

import numpy as np

from repro.core import server as server_lib
from repro.core.errors import (
    ChecksumError,
    ConsistencyError,
    ServerUnavailableError,
    StaleHandleError,
    TensorHubError,
    VersionUnavailableError,
)
from repro.core.meta import DEFAULT_CHUNK_BYTES, DEFAULT_WINDOW, WorkerInfo
from repro.core.server import Assignment, ReferenceServer, SourceSlice, offload_name
from repro.obs import telemetry as obs
from repro.resharding import rowgrid
from repro.transfer import checksum as checksum_lib
from repro.transfer import codec as codec_lib
from repro.transfer.engine import (
    LocalTransport,
    TransportError,
    WorkerRegistry,
    WorkerStore,
)
from repro.transfer.faults import DEFAULT_RETRY_POLICY, RetryPolicy

_POLL = 0.02  # condition re-check period (seconds)

#: op-id namespaces for post-failover re-assertion (keyed by version so
#: every shard of a group derives the same id without coordination);
#: disjoint from the per-handle sequences (0.. and 1_000_000..)
_REASSERT_PUBLISH_BASE = 2_000_000
_REASSERT_BEGIN_BASE = 3_000_000
_REESTABLISH_BASE = 4_000_000  # distinct from the reassert begin: the two
# can target the same version with different op kinds (begin_update vs the
# parked begin_replicate), and one op id must never carry both


class _SourceLost(Exception):
    """Internal: the assigned source failed us mid-pull; report with the
    carried evidence class ("fatal" | "transient" | "corrupt"), re-route
    and resume. Fatal evidence evicts the source (fail-stop, 4.5);
    transient/corrupt evidence accumulates quarantine strikes instead."""

    def __init__(self, source: str, evidence: str = "fatal") -> None:
        super().__init__(source)
        self.source = source
        self.evidence = evidence


#: one data-plane fetch: a whole transfer unit, or a byte sub-range of
#: one; ``owner`` is the plan slice the server assigned it to (load hint)
_PullTask = collections.namedtuple("_PullTask", "unit offset nbytes owner")


def _link_class(source: str, transport: str) -> str:
    """Link class for byte accounting, aligned with the simulator's link
    tags: WAN-negotiated TCP slices ride the VPC gateway, offload twins
    the PCIe bus, everything else the RDMA fabric."""
    if source.endswith("@offload"):
        return "pcie"
    return "vpc_up" if transport == "tcp" else "rdma"


#: re-exported for callers that imported it from here historically
from repro.core.meta import dtype_from_str  # noqa: E402


class TensorHubClient:
    """Process-wide client endpoint: server + transport + registry."""

    def __init__(
        self,
        server: ReferenceServer,
        *,
        registry: Optional[WorkerRegistry] = None,
        transport: Optional[LocalTransport] = None,
        clock: Callable[[], float] = time.monotonic,
        window: int = DEFAULT_WINDOW,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
        failover_timeout: float = 30.0,
        recorder: Optional[obs.Recorder] = None,
        retry_policy: Optional[RetryPolicy] = None,
        faults=None,
    ) -> None:
        self.server = server
        self.registry = registry or WorkerRegistry()
        #: telemetry recorder shared with the transport; disabled by
        #: default so the hot paths stay allocation-free
        self.recorder = obs.DISABLED if recorder is None else recorder
        #: gray-failure self-healing knobs (per-read deadline, bounded
        #: retries, hedged-read straggler threshold) shared by every handle
        self.retry_policy = (
            DEFAULT_RETRY_POLICY if retry_policy is None else retry_policy
        )
        #: ``faults`` (a ThreadedFaultInjector) only applies to the
        #: default transport built here; an explicitly passed transport
        #: carries its own injector (or none)
        self.transport = transport or LocalTransport(
            self.registry, recorder=self.recorder, faults=faults
        )
        self.clock = clock
        #: data-plane knobs inherited by every handle opened through this
        #: client: concurrent unit fetches per shard, and the sub-unit
        #: chunk threshold (None disables chunking). window=1 + no
        #: chunking reproduces the sequential one-fetch-at-a-time loop.
        self.window = max(1, window)
        self.chunk_bytes = (
            int(chunk_bytes) if chunk_bytes and chunk_bytes > 0 else None
        )
        #: how long a blocked server call waits for failover() to install
        #: a recovered server after a controller crash
        self.failover_timeout = failover_timeout
        self._handles: List["ShardHandle"] = []
        self._cv = threading.Condition(threading.RLock())
        server.add_watcher(self._wake)

    # -- controller failover ---------------------------------------------------

    def call(self, method: str, *args, **kwargs):
        """Invoke a server method, riding out a controller crash.

        Caller must hold ``self._cv``. On :class:`ServerUnavailableError`
        the call parks until :meth:`failover` installs a recovered server,
        then retries there. Retrying across the crash is safe because
        every control-plane op is idempotent under re-delivery (group ops
        return their cached result; progress reports are max-based)."""
        rec = self.recorder
        if not rec.enabled:
            while True:
                srv = self.server
                try:
                    return getattr(srv, method)(*args, **kwargs)
                except ServerUnavailableError:
                    self._await_failover(srv)
        t0 = rec.clock()
        try:
            while True:
                srv = self.server
                try:
                    return getattr(srv, method)(*args, **kwargs)
                except ServerUnavailableError:
                    self._await_failover(srv)
        finally:
            rec.counter_add(obs.CTR_CONTROL, rec.clock() - t0)

    def _wait(self, timeout: float = _POLL) -> None:
        """Park on the client condition; accounted as plan-wait stall."""
        rec = self.recorder
        if not rec.enabled:
            self._cv.wait(timeout)
            return
        t0 = rec.clock()
        self._cv.wait(timeout)
        rec.counter_add(obs.CTR_PLAN_WAIT, rec.clock() - t0)

    def _await_failover(self, crashed: ReferenceServer) -> None:
        deadline = time.monotonic() + self.failover_timeout
        while self.server is crashed:
            if time.monotonic() > deadline:
                raise ServerUnavailableError(
                    "controller down and no failover server installed "
                    f"within {self.failover_timeout}s"
                )
            # plain cv wait: call() is already timing this parked period
            # as control-plane stall, so don't also count it as plan-wait
            self._cv.wait(_POLL)

    def failover(self, new_server: ReferenceServer) -> None:
        """Switch every handle to a recovered/standby server (built by
        ``repro.core.failover.recover``) after the primary crashed.

        Handles re-assert whatever durable state the recovered server may
        have lost from the unflushed log tail — their registration, their
        published version, and their in-flight replicate/update op — and
        blocked calls then resume transparently; in-flight pulls pick up
        the re-issued plan through the existing epoch machinery and
        resume from their completed prefix.

        Re-assertion is two-phase across ALL handles: every handle first
        re-establishes its steady state (open/register/publish), and only
        then are in-flight begin ops re-issued. Ordering matters — a
        reader's re-issued ``begin_update("latest")`` must not resolve
        against a server whose publisher has not re-published yet (it
        would come back not-updated and strand the mid-pull threads)."""
        with self._cv:
            if new_server is self.server:
                return
            self.server = new_server
            new_server.add_watcher(self._wake)
            for phase in ("steady", "inflight"):
                for h in list(self._handles):
                    try:
                        h.reassert(phase)
                    except TensorHubError as e:  # pragma: no cover - diagnostics
                        import logging

                        logging.getLogger(__name__).warning(
                            "%s: reassert (%s) after failover failed: %s",
                            h.worker.worker_id,
                            phase,
                            e,
                        )
            self._cv.notify_all()

    def _wake(self) -> None:
        # The watcher fires while the server mutation holds our lock (all
        # server calls go through `self._cv`). Out-of-band mutations (test
        # harnesses injecting failures) are tolerated: waiters re-poll on
        # their own timeout.
        try:
            self._cv.notify_all()
        except RuntimeError:
            pass

    def open(
        self,
        model_name: str,
        replica_name: str,
        num_shards: int,
        shard_idx: int,
        *,
        retain: Optional[object] = None,
        datacenter: str = "dc0",
        node: Optional[str] = None,
        is_spot: bool = False,
        offload_seeding: bool = False,
        with_checksums: bool = True,
        device_repack: bool = False,
        window: Optional[int] = None,
        chunk_bytes: Optional[int] = None,
    ) -> "ShardHandle":
        worker = WorkerInfo(
            worker_id=f"{replica_name}/shard{shard_idx}",
            node=node or f"{datacenter}/{replica_name}",
            datacenter=datacenter,
            is_spot=is_spot,
        )
        handle = ShardHandle(
            client=self,
            model=model_name,
            replica=replica_name,
            shard_idx=shard_idx,
            num_shards=num_shards,
            worker=worker,
            retain=retain,
            offload_seeding=offload_seeding,
            with_checksums=with_checksums,
            device_repack=device_repack,
            window=self.window if window is None else max(1, window),
            chunk_bytes=self.chunk_bytes if chunk_bytes is None else (
                int(chunk_bytes) if chunk_bytes and chunk_bytes > 0 else None
            ),
        )
        with self._cv:
            # open + handle registration under ONE cv hold: a failover
            # interleaved between them would miss the handle in the
            # reassert sweep while its open record sat in the lost tail
            self.call(
                "open",
                model_name,
                replica_name,
                num_shards,
                shard_idx,
                worker=worker,
                retain=retain,
            )
            self._handles.append(handle)
        return handle

    # -- background heartbeats --------------------------------------------------

    def start_heartbeats(
        self, interval: float, *, clock: Optional[Callable[[], float]] = None
    ) -> None:
        """Heartbeat every open handle on a daemon thread.

        The in-process tests drive heartbeats explicitly with virtual
        timestamps; a networked worker wants them ambient, on wall-clock
        time (``time.time`` by default — shared across processes, so a
        restarted controller's expiry ticks compare against the same
        axis). An evicted handle's ``StaleHandleError`` is swallowed:
        eviction is the *server's* verdict and the worker learns it
        through its event poll, not by crashing the heartbeat loop."""
        if getattr(self, "_hb_thread", None) is not None:
            return
        hb_clock = time.time if clock is None else clock
        self._hb_stop = threading.Event()

        def loop() -> None:
            while not self._hb_stop.wait(interval):
                for h in list(self._handles):
                    try:
                        h.heartbeat(hb_clock())
                    except TensorHubError:
                        continue

        self._hb_thread = threading.Thread(
            target=loop, name="tensorhub-heartbeats", daemon=True
        )
        self._hb_thread.start()

    def stop_heartbeats(self) -> None:
        if getattr(self, "_hb_thread", None) is None:
            return
        self._hb_stop.set()
        self._hb_thread.join(timeout=2.0)
        self._hb_thread = None


class ShardHandle:
    """Handle for one shard of one replica (Table 2)."""

    def __init__(
        self,
        *,
        client: TensorHubClient,
        model: str,
        replica: str,
        shard_idx: int,
        num_shards: int,
        worker: WorkerInfo,
        offload_seeding: bool,
        with_checksums: bool,
        retain: Optional[object] = None,
        device_repack: bool = False,
        window: int = DEFAULT_WINDOW,
        chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    ) -> None:
        self.client = client
        self.model = model
        self.replica = replica
        self.shard_idx = shard_idx
        self.num_shards = num_shards
        self.worker = worker
        self.retain = retain
        self.offload_seeding = offload_seeding
        self.with_checksums = with_checksums
        #: windowed data plane: concurrent unit fetches for this shard's
        #: pulls, and the sub-unit chunk threshold (None = off)
        self.window = window
        self.chunk_bytes = chunk_bytes
        #: repack staged reshard bytes through the Pallas gather kernel
        #: (repro.kernels.repack) instead of the NumPy reference path
        self.device_repack = device_repack
        self.store = WorkerStore(worker.worker_id)
        self.current_version: Optional[int] = None
        #: lifetime count of striped interval reads this handle completed
        #: across all reshard pulls (per-interval progress; the
        #: server-visible counter advances in completed destination units)
        self.intervals_pulled = 0
        self._op_seq = 0
        self._off_op_seq = 1_000_000  # twin namespace, disjoint from main ops
        self._offload_stores: Dict[int, WorkerStore] = {}
        self._seed_threads: Dict[int, threading.Thread] = {}
        self._closed = False
        #: failover re-assertion state: whether register() ran, and the
        #: in-flight blocking op — (kind, spec, op_id) — if a replicate or
        #: update is mid-pull when the controller dies
        self._registered = False
        self._inflight: Optional[tuple] = None
        #: (version, op_id) of our last publish(): a post-failover
        #: re-publish re-joins the same group op, so shards that did make
        #: it into the durable log and shards that did not converge on one
        #: transaction
        self._publish_op: Optional[tuple] = None

    # -- helpers ---------------------------------------------------------------

    @property
    def _cv(self) -> threading.Condition:
        return self.client._cv

    @property
    def _server(self) -> ReferenceServer:
        return self.client.server

    def _next_op(self) -> int:
        op = self._op_seq
        self._op_seq += 1
        return op

    def _next_off_op(self) -> int:
        op = self._off_op_seq
        self._off_op_seq += 1
        return op

    def _scall(self, method: str, *args, **kwargs):
        """Server call with controller-failover retry (cv must be held)."""
        return self.client.call(method, *args, **kwargs)

    # -- controller failover (see TensorHubClient.failover) ---------------------

    def reassert(self, phase: str = "steady") -> None:
        """Re-establish this shard's control-plane state on a freshly
        recovered server that may have lost an unflushed suffix of the op
        log. Called under the client cv by ``TensorHubClient.failover``,
        once per phase: ``"steady"`` (open/register/publish) runs for
        every handle before any ``"inflight"`` begin re-issue, so a
        reader's ``begin_update("latest")`` never resolves against a
        server whose publisher has not re-published yet.

        Everything re-issued here is idempotent against a server that did
        NOT lose the corresponding records: re-opening an open shard is
        absorbed, register() is a set-add, and a re-delivered group op
        returns its cached result. In-flight pull threads then self-heal:
        the re-issued begin installs fresh in-progress state, their next
        epoch check triggers a re-plan, and max-based progress reports
        re-assert the completed prefix."""
        if self._closed:
            return
        srv = self.client.server
        if phase == "steady":
            try:
                srv.open(
                    self.model,
                    self.replica,
                    self.num_shards,
                    self.shard_idx,
                    worker=self.worker,
                    retain=self.retain,
                )
            except ConsistencyError:
                pass  # this shard is already open on the recovered server
            if self._registered:
                srv.register(self.model, self.replica, self.shard_idx)
            # if the recovered server lost our publish (all shards, or
            # just this one — another shard's record, or its reassert,
            # may already have re-installed the version), vouch for the
            # registered bytes again (fresh manifest — buffers are
            # immutable while published, so it is identical)
            if (
                self._inflight is None
                and self.current_version is not None
                and self._shard_publish_lost(srv)
            ):
                v = self.current_version
                if self._publish_op is not None and self._publish_op[0] == v:
                    # re-join the original publish group op, so durable
                    # and lost shards converge on one transaction
                    op = self._publish_op[1]
                else:
                    op = _REASSERT_PUBLISH_BASE + v
                srv.publish(
                    self.model,
                    self.replica,
                    self.shard_idx,
                    v,
                    self.store.build_manifest(with_checksums=self.with_checksums),
                    op_id=op,
                )
            return
        infl = self._inflight
        if infl is None:
            return
        kind, spec, op, pinned = infl
        if pinned is not None:
            # mid-pull of a KNOWN version: re-issue pinned to it under a
            # version-derived op id — a relative spec like "latest" may
            # resolve differently on the recovered server (a newer
            # publish survived in the log), and installing in-progress
            # state for any other version would strand the pull threads.
            # Against a server that retained the original state this
            # degenerates to a no-op ("already current" / mutability
            # rejection on a fresh op id).
            op2 = _REASSERT_BEGIN_BASE + pinned
            try:
                if kind == "replicate":
                    srv.begin_replicate(
                        self.model, self.replica, self.shard_idx, pinned, op_id=op2
                    )
                else:
                    srv.begin_update(
                        self.model,
                        self.replica,
                        self.shard_idx,
                        pinned,
                        op_id=op2,
                        offload_seeding=self.offload_seeding,
                    )
            except TensorHubError:
                pass  # state (partially) present; pulls self-heal via epochs
            return
        # begin not yet answered: re-issue the original op verbatim —
        # cached result if the server kept the txn, fresh (identical)
        # execution if the log tail lost it
        if kind == "replicate":
            srv.begin_replicate(
                self.model, self.replica, self.shard_idx, spec, op_id=op
            )
        else:
            srv.begin_update(
                self.model,
                self.replica,
                self.shard_idx,
                spec,
                op_id=op,
                offload_seeding=self.offload_seeding,
            )

    def _reestablish(self, version: int, dest_name: str) -> None:
        """Last-resort recovery for a pull whose in-progress state is
        missing from the (recovered) server and whose re-issued begin
        could not restore it — e.g. the target version's publisher lives
        in ANOTHER client process that has not failed over yet, so
        reassert ordering cannot help. Park a replicate for the absolute
        version we were pulling: ``_service_pending`` assigns it the
        moment a source (re)appears, and the waiting pull threads resume
        from their completed prefix. cv must be held."""
        if dest_name != self.replica or self._inflight is None:
            return
        try:
            self._scall(
                "begin_replicate",
                self.model,
                self.replica,
                self.shard_idx,
                version,
                op_id=_REESTABLISH_BASE + version,
            )
        except TensorHubError:
            pass  # state partially present (e.g. old version still held)

    def _shard_publish_lost(self, srv: ReferenceServer) -> bool:
        """Whether the recovered server is missing THIS shard's record of
        our published version (whole-version loss or a partial group)."""
        v = self.current_version
        if srv.replica_version(self.model, self.replica) != v:
            return True
        try:
            return srv.shard_progress(self.model, self.replica, v, self.shard_idx) == 0
        except TensorHubError:
            return True

    # -- Table 2: register / unregister -----------------------------------------

    def register(
        self,
        named_tensors: Mapping[str, np.ndarray],
        *,
        layout: Optional[Mapping[str, tuple]] = None,
    ) -> None:
        """Register weight buffers. ``layout`` maps tensor name to
        ``(global_shape, offset)`` — the layout descriptor that makes this
        shard a valid source/destination for cross-layout resharding
        (see ``repro.resharding``; ``tp_shard`` builds it)."""
        self.store.register(named_tensors, layout=layout)
        self.client.registry.add(self.replica, self.shard_idx, self.store)
        with self._cv:
            self._scall("register", self.model, self.replica, self.shard_idx)
            self._registered = True

    def unregister(self) -> None:
        with self._cv:
            self._scall("unregister", self.model, self.replica, self.shard_idx)
            self._registered = False
        self.client.registry.remove(self.replica, self.shard_idx)
        self.store.unregister()

    # -- Table 2: publish / unpublish --------------------------------------------

    def publish(self, version: int) -> None:
        rec = self.client.recorder
        sp = (
            rec.span("publish", track=self.worker.worker_id, version=version)
            if rec.enabled
            else None
        )
        try:
            # publishing vouches for every registered byte: lift any
            # watermark a previously aborted pull left on the store
            self.store.serving_prefix = None
            manifest = self.store.build_manifest(with_checksums=self.with_checksums)
            op = self._next_op()
            with self._cv:
                self._scall(
                    "publish",
                    self.model, self.replica, self.shard_idx, version, manifest, op_id=op
                )
            self.current_version = version
            self._publish_op = (version, op)
        finally:
            if sp is not None:
                sp.end()

    def unpublish(self) -> None:
        # snapshot the retiring version as the delta base BEFORE telling
        # the server: once unpublish lands, the server may negotiate
        # residuals against this replica's prior version, and the
        # snapshot must already exist when the first delta read arrives
        v = self.current_version
        if v is not None:
            self.store.snapshot_base(v)
        op = self._next_op()
        with self._cv:
            res = self._scall(
                "unpublish", self.model, self.replica, self.shard_idx, op_id=op
            )
        if res.offload_required:
            assert res.offload_version is not None
            self._do_retention_offload(res.offload_version)
        self._wait_drained()
        self.current_version = None
        self.process_events()

    def _do_retention_offload(self, version: int) -> None:
        """Retention protocol (3.3): copy this shard to host memory and
        publish the copy before the GPU buffers may be reused."""
        off_store = WorkerStore(f"{self.worker.worker_id}@offload")
        self.store.snapshot_to(off_store)
        self._offload_stores[version] = off_store
        self.client.registry.add(offload_name(self.replica), self.shard_idx, off_store)
        manifest = off_store.build_manifest(with_checksums=self.with_checksums)
        op = self._next_op()
        with self._cv:
            self._scall(
                "publish_offload",
                self.model, self.replica, self.shard_idx, version, manifest, op_id=op
            )

    def _wait_drained(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._scall("finish_unpublish", self.model, self.replica):
                if deadline is not None and time.monotonic() > deadline:
                    raise TensorHubError(f"{self.replica}: drain timed out")
                self.client._wait(_POLL)

    # -- Table 2: replicate / update ----------------------------------------------

    def replicate(self, version: object = "latest", *, timeout: Optional[float] = None) -> int:
        """Materialize ``version`` into the registered tensors; blocks until
        the version exists. Returns the absolute version fetched."""
        op = self._next_op()
        deadline = None if timeout is None else time.monotonic() + timeout
        rec = self.client.recorder
        sp = (
            rec.span("replicate", track=self.worker.worker_id)
            if rec.enabled
            else None
        )
        try:
            with self._cv:
                self._inflight = ("replicate", version, op, None)
                assignment = self._scall(
                    "begin_replicate",
                    self.model, self.replica, self.shard_idx, version, op_id=op
                )
                while assignment is None:
                    if deadline is not None and time.monotonic() > deadline:
                        raise VersionUnavailableError(
                            f"{self.model} {version!r}: not published within timeout"
                        )
                    self.client._wait(_POLL)
                    assignment = self._scall("redeem", self.model, self.replica, op_id=op)
                # pin the in-flight op to the RESOLVED version: "latest"
                # may resolve differently on a recovered server, and a
                # reassert must restore the version this pull is pulling
                self._inflight = ("replicate", version, op, assignment.version)
            self._note_assignment(assignment)
            self._pull(assignment, op_id=op, dest_name=self.replica, dest_store=self.store)
            self.current_version = assignment.version
        finally:
            with self._cv:
                self._inflight = None
            if sp is not None:
                sp.end()
        self.process_events()
        return assignment.version

    def update(self, version: object = "latest") -> bool:
        """Atomically switch to a newer version if available (Table 2)."""
        prev = self.current_version
        op = self._next_op()
        rec = self.client.recorder
        sp = None
        try:
            with self._cv:
                self._inflight = ("update", version, op, None)
                d = self._scall(
                    "begin_update",
                    self.model,
                    self.replica,
                    self.shard_idx,
                    version,
                    op_id=op,
                    offload_seeding=self.offload_seeding,
                )
                if d.updated and d.version is not None:
                    # pin to the resolved version (see replicate())
                    self._inflight = ("update", version, op, d.version)
            if d.seed_started and d.seed_version is not None:
                self._spawn_seed_pull(d.seed_version)
            if not d.updated:
                self.process_events()
                return False
            if rec.enabled:
                sp = rec.span("update", track=self.worker.worker_id, version=d.version)
            if d.offload_required and d.offload_version is not None:
                self._do_retention_offload(d.offload_version)
            self._wait_drained()
            # the buffers still hold the retiring version: snapshot them
            # as the delta base (this replica may later SERVE residuals
            # to a peer updating from the same prior version; the pull
            # below also decodes incoming residuals against these bytes,
            # still live in the buffers until each unit is overwritten)
            if prev is not None:
                self.store.snapshot_base(prev)
            assert d.assignment is not None
            self._note_assignment(d.assignment)
            self._pull(d.assignment, op_id=op, dest_name=self.replica, dest_store=self.store)
            self.current_version = d.version
        finally:
            with self._cv:
                self._inflight = None
            if sp is not None:
                sp.end()
        self.process_events()
        return True

    def _note_assignment(self, assignment: Assignment) -> None:
        """Record an assignment/epoch event on this shard's timeline."""
        rec = self.client.recorder
        if not rec.enabled:
            return
        rec.event(
            "assignment",
            track=self.worker.worker_id,
            version=assignment.version,
            epoch=assignment.epoch,
            sources=[s.source for s in assignment.sources],
            codec=assignment.codec,
        )

    # -- Table 2: list / wait / close ------------------------------------------------

    def list(self) -> Dict[int, set]:
        with self._cv:
            return self._scall("list_versions", self.model)

    def wait(self, predicate: Callable[[Dict[int, set]], bool], *, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not predicate(self._scall("list_versions", self.model)):
                if deadline is not None and time.monotonic() > deadline:
                    raise TensorHubError("wait(): predicate not satisfied within timeout")
                self.client._wait(_POLL)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for t in self._seed_threads.values():
            t.join(timeout=5.0)
        try:
            if self.current_version is not None:
                self.unpublish()
        except ServerUnavailableError:
            raise  # dead controller, not a dead source/handle
        except (StaleHandleError, TensorHubError):
            pass
        with self._cv:
            self._scall("close", self.model, self.replica, self.shard_idx)
            if self in self.client._handles:
                self.client._handles.remove(self)
        self.client.registry.remove(self.replica, self.shard_idx)
        self.client.registry.remove(offload_name(self.replica), self.shard_idx)

    # -- housekeeping -----------------------------------------------------------------

    def heartbeat(self, now: Optional[float] = None) -> None:
        with self._cv:
            self._scall(
                "heartbeat",
                self.model, self.replica, self.shard_idx,
                self.client.clock() if now is None else now,
            )

    def process_events(self) -> None:
        """Drain server events: free released offload buffers (3.3)."""
        with self._cv:
            events = self._scall("poll_events", self.worker.worker_id)
        for ev in events:
            if ev.kind == "offload_release" and ev.version is not None:
                store = self._offload_stores.pop(ev.version, None)
                if store is not None:
                    store.unregister()
                if not self._offload_stores:
                    self.client.registry.remove(offload_name(self.replica), self.shard_idx)

    # -- data plane ---------------------------------------------------------------------

    def _wait_src_manifest(
        self, version: int, source: str, shard_idx: Optional[int] = None
    ):
        """Wait for the assigned source replica's manifest for one of its
        shards. Resolution is by *replica* (falling back to its count
        family), so a same-count source sharded along different axes
        cannot be mistaken for our own layout."""
        idx = self.shard_idx if shard_idx is None else shard_idx
        with self._cv:
            while True:
                m = self._scall("replica_manifest", self.model, version, source, idx)
                if m is not None:
                    return m
                try:  # liveness: don't wait forever on an evicted source
                    self._scall("shard_progress", self.model, source, version, idx)
                except ServerUnavailableError:
                    raise  # dead controller, not a dead source/handle
                except (StaleHandleError, TensorHubError):
                    raise _SourceLost(source)
                self.client._wait(_POLL)

    def _pull(
        self,
        assignment: Assignment,
        *,
        op_id: int,
        dest_name: str,
        dest_store: WorkerStore,
        twin: bool = False,
    ) -> None:
        """The replication loop (4.3.3): repeatedly read the source's
        progress counter, fetch the available prefix, advance our own
        counter; re-route on source failure (4.5).

        Same-layout sources serve whole transfer units shard-to-shard;
        a source with a different shard count is served by the reshard
        path (striped interval reads + repack). Progress counts completed
        *destination* units in both cases, so a re-route mid-transfer may
        switch pull modes and still resume from the same counter — the
        replacement source can have yet another layout (re-planning).

        ``complete_replicate`` gets its *own* op id, allocated here — the
        allocation point is the same in every shard's program order (SPMD),
        so the group op keys stay aligned without ever reusing the begin
        op's id (whose transaction may still be open on slow shards).
        """
        del op_id  # the begin op id; completion uses a fresh one (below)
        version = assignment.version
        done = 0
        used_reshard = False
        # lossy wire codecs (cross-DC int8): decoded bytes diverge from
        # the publisher's, so readers chaining off us (or off anyone
        # downstream of the lossy hop — divergence propagates along raw
        # chains) must not verify against the publisher's manifest
        # checksums. The span loop registers a zero-checksum manifest the
        # moment a divergent plan is detected (mirroring the reshard
        # path), and the epilogue below upgrades it to our real checksums
        # once the bytes are final.
        pull_state = {"divergent": False, "rejects": {}}
        # swarm replication: while this pull is in flight the store serves
        # other readers exactly its completed prefix; the watermark is
        # advanced before every server progress report and lifted when the
        # pull completes (see WorkerStore.serving_prefix).
        dest_store.serving_prefix = 0
        reshard_rejects: Dict[int, int] = {}  # persists across re-plans
        while True:
            # the server-side counter is authoritative (max-based): a span
            # that advanced it before the source died resumes from there,
            # not from this attempt's stale local count
            with self._cv:
                try:
                    done = max(
                        done,
                        self._scall(
                            "shard_progress",
                            self.model, dest_name, version, self.shard_idx,
                        ),
                    )
                except ServerUnavailableError:
                    raise  # dead controller, not a dead source/handle
                except (StaleHandleError, TensorHubError):
                    pass  # no in-progress state yet (first span)
            if dest_store.serving_prefix is not None:
                dest_store.serving_prefix = max(dest_store.serving_prefix, done)
            try:
                reshard = assignment.resharded
                src_manifest = None
                if not reshard:
                    # equal shard counts are necessary but not sufficient:
                    # a same-count source sliced along other axes must go
                    # through the reshard path too, or unit copies would
                    # silently scramble weights
                    src_manifest = self._wait_src_manifest(version, assignment.source)
                    reshard = not src_manifest.same_layout(
                        dest_store.build_manifest(with_checksums=False)
                    )
                if reshard:
                    used_reshard = True
                    done = self._pull_resharded_span(
                        assignment, dest_name, dest_store, done,
                        rejects=reshard_rejects,
                    )
                else:
                    done = self._pull_units_span(
                        assignment, dest_name, dest_store, done, src_manifest,
                        pull_state,
                    )
                break
            except _SourceLost as e:
                assignment = self._handle_source_failure(
                    dest_name, e.source, e.evidence
                )
        dest_store.serving_prefix = None  # fully replicated: unrestricted
        if (used_reshard or pull_state["divergent"]) and self.with_checksums:
            # our layout family was registered with zero checksums (pre-pull
            # buffers / lossy-decoded bytes mid-flight); now that the bytes
            # are final, upgrade it so readers chaining off us get
            # end-to-end verification back
            rec = self.client.recorder
            t0 = rec.clock() if rec.enabled else 0.0
            manifest = dest_store.build_manifest(with_checksums=True)
            if rec.enabled:
                # checksumming the whole shard is verify work — without it
                # the stall components would not tile the pull wall time
                rec.counter_add(obs.CTR_VERIFY, rec.clock() - t0)
                rec.event("manifest_upgrade", track=dest_name, version=version)
            with self._cv:
                self._scall(
                    "put_manifest",
                    self.model,
                    dest_name,
                    self.shard_idx,
                    version,
                    manifest,
                )
        complete_op = self._next_off_op() if twin else self._next_op()
        with self._cv:
            self._scall(
                "complete_replicate",
                self.model, dest_name, self.shard_idx, version, op_id=complete_op
            )

    def _pull_units_span(
        self,
        assignment: Assignment,
        dest_name: str,
        dest_store: WorkerStore,
        done: int,
        manifest,
        pull_state: Optional[dict] = None,
    ) -> int:
        """Same-layout pull: whole transfer units (or byte-range chunks of
        them), shard i <- shard i, against the source replicas' manifests
        (schema + checksums). Multi-source assignments partition the unit
        list across replicas; the windowed executor keeps up to ``window``
        fetches in flight and advances the progress counter strictly over
        the completed prefix."""
        version = assignment.version
        units = manifest.units
        completed: Set[int] = set()
        if pull_state is None:
            pull_state = {"divergent": False}
        # per-destination-unit checksum-reject counts: persists across
        # re-plans so a genuinely corrupt unit (every source serves bad
        # bytes) aborts after retry_limit rejects instead of looping
        rejects: Dict[int, int] = pull_state.setdefault("rejects", {})
        while done < len(units):
            slices = assignment.slices(len(units))
            if not pull_state["divergent"] and self._divergent_pull(
                assignment, manifest, version
            ):
                # Our bytes will diverge from the count-family (publisher)
                # manifest — either a lossy slice decodes in this plan, or
                # we are chaining off a replica whose own bytes already
                # diverged (its manifest checksums differ from the
                # family's). Register a zero-checksum manifest for
                # ourselves BEFORE serving any prefix so chained readers
                # skip publish-time verification against bytes we don't
                # hold; the pull epilogue upgrades it to our real
                # (decoded-byte) checksums.
                pull_state["divergent"] = True
                with self._cv:
                    self._scall(
                        "put_manifest",
                        self.model,
                        dest_name,
                        self.shard_idx,
                        version,
                        dest_store.build_manifest(with_checksums=False),
                    )
            if self.window <= 1 and self.chunk_bytes is None and len(slices) == 1:
                return self._pull_units_seq(
                    assignment, dest_name, dest_store, done, manifest, rejects
                )
            completed -= set(range(done))
            slices = self._validated_slices(slices, version, manifest)
            outcome, done = self._pull_units_windowed(
                assignment, slices, dest_name, dest_store, done, manifest,
                completed, rejects,
            )
            if outcome == "replan":
                with self._cv:
                    new = self._scall("get_assignment", self.model, dest_name)
                    if new is None:
                        # in-progress state vanished: a controller failover
                        # lost it and this client's reassert could not
                        # restore it (e.g. the publisher lives in another
                        # process that has not failed over yet). Park a
                        # replicate for the absolute version and wait for
                        # a source to (re)appear.
                        self._reestablish(version, dest_name)
                        deadline = (
                            time.monotonic() + self.client.failover_timeout
                        )
                        while new is None:
                            if time.monotonic() > deadline:
                                raise StaleHandleError(
                                    f"{dest_name}: in-progress state for "
                                    f"v{version} not re-established after "
                                    "controller failover"
                                )
                            self.client._wait(_POLL)
                            new = self._scall(
                                "get_assignment", self.model, dest_name
                            )
                if new is not None and not new.resharded:
                    assignment = new
                # a resharded refetch loops and retries on the same
                # plan; a dead source surfaces as _SourceLost upstream
        return done

    def _divergent_pull(self, assignment: Assignment, manifest, version: int) -> bool:
        """Whether this pull will leave us with bytes whose checksums
        differ from the count-family (publisher) manifest — readers
        resolving us through the family fallback would then mis-verify.
        True when any negotiated codec in the plan is lossy, or when the
        source manifest we verify against already carries non-family
        checksums (the source itself descends from a lossy transfer:
        divergence propagates down raw chains)."""
        if codec_lib.assignment_lossy(assignment):
            return True
        with self._cv:
            fam = self._scall(
                "manifest",
                self.model,
                version,
                self.shard_idx,
                num_shards=self.num_shards,
            )
        return fam is not None and tuple(fam.checksums) != tuple(manifest.checksums)

    def _validated_slices(
        self, slices: List[SourceSlice], version: int, manifest
    ) -> List[SourceSlice]:
        """Unit pulls are interchangeable only between byte-identical
        layouts; drop any sibling source whose manifest diverges from the
        primary's (the server filters too — this is the client-side
        guard). The primary is never dropped.

        Layout identity alone is not enough: the windowed executor
        verifies every unit against the *primary's* checksums, so a
        sibling must also hold the same bytes. A replica whose manifest
        carries different checksums (it descends from a lossy int8 hop
        while the primary holds publisher bytes, or vice versa) would
        fail verification — or worse, silently mix byte provenance with
        checksums off — so it is dropped from the plan."""
        if len(slices) <= 1:
            return slices
        kept = [slices[0]]
        for sl in slices[1:]:
            m = self._wait_src_manifest(version, sl.source)
            if m.same_layout(manifest) and tuple(m.checksums) == tuple(
                manifest.checksums
            ):
                kept.append(sl)
        return kept

    def _pull_units_seq(
        self,
        assignment: Assignment,
        dest_name: str,
        dest_store: WorkerStore,
        done: int,
        manifest,
        rejects: Optional[Dict[int, int]] = None,
    ) -> int:
        """The pre-scheduler data plane: one whole-unit fetch at a time
        from a single source (window=1, chunking off)."""
        version = assignment.version
        units = manifest.units
        source = assignment.source
        codec = assignment.codec
        rec = self.client.recorder
        track = self.worker.worker_id
        lc = _link_class(source, assignment.transport)
        policy = self.client.retry_policy
        if rejects is None:
            rejects = {}
        while done < len(units):
            avail = self._await_source_progress(source, version, self.shard_idx, done)
            for i in range(done, avail):
                sp = None
                if rec.enabled:
                    t0 = rec.clock()
                    sp = rec.span(
                        "pull_unit", track=track, source=source, codec=codec,
                        unit=units[i].name, bytes=units[i].nbytes, link_class=lc,
                    )
                try:
                    self._retry_transient(
                        lambda i=i: self.client.transport.pull_unit(
                            source, self.shard_idx, units[i],
                            manifest.checksums[i], dest_store, codec=codec,
                            link_class=lc, track=track,
                        ),
                        source,
                        unit=units[i].name,
                    )
                except TransportError as e:
                    if dest_store.failed:
                        # OUR store died (preemption): the write guard
                        # fired, not the source — blaming the source
                        # would evict a healthy replica cluster-wide
                        raise
                    raise _SourceLost(
                        source,
                        evidence="transient"
                        if getattr(e, "transient", False)
                        else "fatal",
                    )
                except (ChecksumError, codec_lib.CodecError):
                    # corrupt bytes from this source: report the evidence
                    # (the server quarantines it and re-plans) and resume
                    # from the prefix instead of aborting the pull. Bounded
                    # per unit: if every re-plan keeps rejecting the same
                    # unit, the data is genuinely bad — propagate. A
                    # CodecError is a torn/misframed wire frame — the
                    # decode-failure twin of a checksum mismatch; it routes
                    # through the same healing (StaleBaseError never
                    # reaches here: the transport resolves delta-base
                    # staleness internally, it is not source corruption).
                    rejects[i] = rejects.get(i, 0) + 1
                    if rejects[i] > policy.retry_limit:
                        raise
                    if rec.enabled:
                        rec.counter_add(obs.CTR_CORRUPT_REJECTS, 1)
                        rec.event(
                            "corrupt_reject", track=track, source=source,
                            unit=units[i].name,
                        )
                    raise _SourceLost(source, evidence="corrupt")
                finally:
                    if sp is not None:
                        sp.end()
                        rec.counter_add(obs.CTR_WIRE, rec.clock() - t0)
                done += 1
                dest_store.serving_prefix = done  # before the server learns
                if rec.enabled:
                    rec.event("prefix_advance", track=track, done=done)
                with self._cv:
                    self._scall(
                        "update_progress",
                        self.model, dest_name, self.shard_idx, version, done,
                    )
        return done

    def _build_pull_tasks(
        self,
        slices: List[SourceSlice],
        manifest,
        done: int,
        completed: Set[int],
    ) -> List[_PullTask]:
        """Expand the plan's unit ranges into an ordered task list; units
        above the chunk threshold become byte-range tasks, owner-hinted
        round-robin across all sources (identical bytes everywhere, so a
        giant tensor can aggregate every source's bandwidth).

        With a non-raw codec in the plan, chunk boundaries are aligned up
        to the codec's row granularity so every chunk encodes exactly the
        rows the whole-unit encoding would — chunked giant units then
        reassemble bit-identically to an unchunked transfer."""
        units = manifest.units
        chunk = self.chunk_bytes
        codecs = [codec_lib.get_codec(sl.codec) for sl in slices]
        any_coded = any(c.name != "raw" for c in codecs)
        by_name = {t.name: t for t in manifest.tensors} if any_coded else {}
        owners: Dict[int, int] = {}
        for k, sl in enumerate(slices):
            for ui in range(max(sl.start_unit, done), min(sl.stop_unit, len(units))):
                owners.setdefault(ui, k)
        tasks: List[_PullTask] = []
        rr = 0
        for ui in range(done, len(units)):
            if ui in completed:
                continue
            k = owners.get(ui, 0)
            nbytes = units[ui].nbytes
            if chunk is not None and nbytes > chunk:
                n_parts = -(-nbytes // chunk)
                per = -(-nbytes // n_parts)
                if any_coded:
                    dtype = codec_lib.unit_wire_dtype(by_name, units[ui])
                    per = rowgrid.chunk_align(
                        per,
                        rowgrid.row_granularity(
                            [c.name for c in codecs], dtype
                        ),
                    )
                off = 0
                j = 0
                while off < nbytes:
                    step = min(per, nbytes - off)
                    tgt = (rr + j) % len(slices) if len(slices) > 1 else k
                    tasks.append(_PullTask(ui, off, step, tgt))
                    off += step
                    j += 1
                rr += j
            else:
                tasks.append(_PullTask(ui, 0, nbytes, k))
        return tasks

    def _pull_units_windowed(
        self,
        assignment: Assignment,
        slices: List[SourceSlice],
        dest_name: str,
        dest_store: WorkerStore,
        done: int,
        manifest,
        completed: Set[int],
        rejects: Optional[Dict[int, int]] = None,
    ):
        """Windowed multi-source executor: one worker thread per source
        slice, a shared semaphore capping in-flight fetches at ``window``,
        global in-order task claiming (a worker takes the lowest-indexed
        task its source's progress covers — keeps the prefix counter that
        gates downstream readers advancing at full rate), and whole-unit
        checksum verification after chunk reassembly.

        The span is *supervised*, not joined: a monitor thread watches
        per-task read deadlines and the assignment epoch, so a source
        that hangs mid-read (the gray failure a heartbeat never sees)
        gets reported and the span drains on the resulting re-plan
        instead of pinning the pull forever. Hung daemon workers are
        abandoned safely — every post-read write is gated on the span's
        stop flag and per-task completion claims."""
        version = assignment.version
        units = manifest.units
        tasks = self._build_pull_tasks(slices, manifest, done, completed)
        if not tasks:
            return "done", done
        remaining: Dict[int, int] = {}
        for t in tasks:
            remaining[t.unit] = remaining.get(t.unit, 0) + 1
        shared = {
            "lock": threading.Lock(),
            "sem": threading.Semaphore(self.window),
            "tasks": tasks,
            "claimed": [False] * len(tasks),
            "unclaimed": len(tasks),
            "scan": 0,
            "remaining": remaining,
            "staging": {},  # unit -> np.uint8 reassembly buffer
            "lossy_units": set(),  # units with any lossy-codec chunk
            "completed": completed,  # shared with caller: survives re-plans
            "done": done,
            "stop": None,  # None | "replan" | BaseException
            "epoch": assignment.epoch,
            # self-healing state --------------------------------------
            "rejects": rejects if rejects is not None else {},
            "taskdone": [False] * len(tasks),  # completion claims
            "ntaskdone": 0,
            "inflight": {},  # task idx -> (start_clock, source)
            "durations": [],  # completed read durations (hedge baseline)
            "hedged": set(),  # task idxs already duplicated once
            "done_ev": threading.Event(),
        }
        workers = [
            threading.Thread(
                target=self._span_worker,
                args=(sl, shared, dest_name, dest_store, manifest, version),
                daemon=True,
                name=f"{self.worker.worker_id}-pull-{sl.source}",
            )
            for sl in slices
        ]
        for w in workers:
            w.start()
        self._monitor_span(shared, dest_name, version)
        stop = shared["stop"]
        if isinstance(stop, BaseException):
            raise stop
        if stop == "replan":
            return "replan", shared["done"]
        return "done", shared["done"]

    def _span_stop(self, shared: dict, stop) -> None:
        with shared["lock"]:
            if shared["stop"] is None or (
                isinstance(stop, BaseException)
                and not isinstance(shared["stop"], BaseException)
            ):
                shared["stop"] = stop
        ev = shared.get("done_ev")
        if ev is not None:
            ev.set()

    def _monitor_span(self, shared: dict, dest_name: str, version: int) -> None:
        """Supervise a windowed span: enforce per-read deadlines and
        watch the assignment epoch so hung workers can't pin the span.

        A read in flight longer than ``retry_policy.fail_detect`` is
        *transient* evidence against its source — reported (rate-limited
        per source to one report per detection window) so the server
        strike-counts and, at the quarantine threshold, re-plans around
        it. The epoch bump then drains the span; the hung worker thread
        is abandoned (daemon, post-read writes stop-gated)."""
        ev: threading.Event = shared["done_ev"]
        tasks: List[_PullTask] = shared["tasks"]
        policy = self.client.retry_policy
        rec = self.client.recorder
        track = self.worker.worker_id
        last_report: Dict[str, float] = {}
        while not ev.wait(_POLL):
            now = self.client.clock()
            hung = []
            with shared["lock"]:
                if shared["stop"] is not None:
                    return
                for ti, (started, src) in shared["inflight"].items():
                    if shared["taskdone"][ti]:
                        continue
                    if now - started >= policy.fail_detect:
                        prev = last_report.get(src)
                        if prev is None or now - prev >= policy.fail_detect:
                            last_report[src] = now
                            hung.append((src, tasks[ti].unit))
            for src, unit in hung:
                if rec.enabled:
                    rec.counter_add(obs.CTR_DEADLINE_REPORTS, 1)
                    rec.event(
                        "read_deadline", track=track, source=src, unit=unit,
                    )
                self._report_suspect(dest_name, src, "transient")
            try:
                with self._cv:
                    ep = self._scall(
                        "assignment_epoch", self.model, dest_name, version
                    )
            except ServerUnavailableError:
                raise  # dead controller, not a dead source/handle
            except (StaleHandleError, TensorHubError):
                continue  # workers surface dest eviction themselves
            if ep != shared["epoch"]:
                self._span_stop(shared, "replan")
                return
        # done_ev set: all tasks claimed complete, or a worker stopped us

    def _report_suspect(self, dest_name: str, source: str, evidence: str) -> None:
        """Report non-fatal evidence against a source without waiting for
        a re-route (the monitor keeps polling the epoch instead)."""
        try:
            with self._cv:
                self._scall(
                    "report_transfer_failure",
                    self.model, dest_name, source, evidence,
                    self.client.clock(),
                )
        except ServerUnavailableError:
            raise
        except (StaleHandleError, TensorHubError):
            pass  # handle churn mid-report: the epoch poll handles it

    def _retry_transient(self, fn, source: str, *, unit=None):
        """Run a transport read, retrying transient failures with
        exponential backoff up to ``retry_policy.retry_limit`` attempts
        before letting the error escalate to the failure reporter."""
        policy = self.client.retry_policy
        rec = self.client.recorder
        attempt = 0
        while True:
            try:
                return fn()
            except TransportError as e:
                if not getattr(e, "transient", False) or attempt >= policy.retry_limit:
                    raise
                attempt += 1
                if rec.enabled:
                    rec.counter_add(obs.CTR_RETRIES, 1)
                    rec.event(
                        "retry", track=self.worker.worker_id,
                        source=source, unit=unit, attempt=attempt,
                    )
                time.sleep(policy.backoff(attempt))

    def _hedge_pick(self, shared: dict, sl: SourceSlice, avail: int):
        """Pick a straggling in-flight task worth duplicating onto this
        (idle) source: oldest read exceeding ``hedge_threshold`` × the
        median completed-read duration, owned by a different source, not
        already hedged, and within this source's served prefix. Both
        copies race; the first to finish claims the task, the loser's
        byte-identical result is discarded."""
        policy = self.client.retry_policy
        with shared["lock"]:
            if shared["stop"] is not None:
                return None
            durs = shared["durations"]
            if len(durs) < policy.hedge_min_samples:
                return None
            med = sorted(durs)[len(durs) // 2]
            threshold = policy.hedge_threshold * max(med, 1e-6)
            now = self.client.clock()
            tasks: List[_PullTask] = shared["tasks"]
            pick = None
            oldest = None
            for ti, (started, src) in shared["inflight"].items():
                if src == sl.source or ti in shared["hedged"]:
                    continue
                if shared["taskdone"][ti] or tasks[ti].unit >= avail:
                    continue
                age = now - started
                if age >= threshold and (oldest is None or age > oldest):
                    oldest = age
                    pick = ti
            if pick is not None:
                shared["hedged"].add(pick)
            return pick

    def _span_worker(
        self,
        sl: SourceSlice,
        shared: dict,
        dest_name: str,
        dest_store: WorkerStore,
        manifest,
        version: int,
    ) -> None:
        tasks: List[_PullTask] = shared["tasks"]
        claimed: List[bool] = shared["claimed"]
        rec = self.client.recorder
        policy = self.client.retry_policy
        try:
            while True:
                with shared["lock"]:
                    if (
                        shared["stop"] is not None
                        or shared["ntaskdone"] == len(tasks)
                    ):
                        return
                with self._cv:
                    try:
                        ep = self._scall(
                            "assignment_epoch", self.model, dest_name, version
                        )
                    except ServerUnavailableError:
                        raise  # dead controller, not a dead source/handle
                    except (StaleHandleError, TensorHubError) as e:
                        if self._inflight is not None and dest_name == self.replica:
                            # our own in-progress state is missing — not an
                            # eviction but a controller failover that lost
                            # it; drain the span so the outer loop can
                            # re-establish and resume from the prefix
                            self._span_stop(shared, "replan")
                        else:
                            self._span_stop(shared, e)  # dest evicted mid-pull
                        return
                    try:
                        avail = self._scall(
                            "shard_progress",
                            self.model, sl.source, version, self.shard_idx,
                        )
                    except ServerUnavailableError:
                        raise  # dead controller, not a dead source/handle
                    except (StaleHandleError, TensorHubError):
                        raise _SourceLost(sl.source)
                if ep != shared["epoch"]:
                    self._span_stop(shared, "replan")
                    return
                pick = None
                hedged = False
                with shared["lock"]:
                    while shared["scan"] < len(tasks) and claimed[shared["scan"]]:
                        shared["scan"] += 1
                    for i in range(shared["scan"], len(tasks)):
                        if not claimed[i] and tasks[i].unit < avail:
                            pick = i
                            claimed[i] = True
                            shared["unclaimed"] -= 1
                            break
                if pick is None:
                    # nothing unclaimed this source can serve: duplicate
                    # the slowest foreign in-flight read instead of idling
                    # (bounds single-source straggling at roughly the
                    # healthy source's speed)
                    pick = self._hedge_pick(shared, sl, avail)
                    if pick is not None:
                        hedged = True
                        if rec.enabled:
                            rec.counter_add(obs.CTR_HEDGES, 1)
                            rec.event(
                                "hedge", track=self.worker.worker_id,
                                source=sl.source, unit=tasks[pick].unit,
                            )
                if pick is None:
                    # nothing this source can serve yet: wait for progress
                    with self._cv:
                        self.client._wait(_POLL)
                    continue
                shared["sem"].acquire()
                try:
                    if shared["stop"] is not None:
                        return  # abandoned claim; the re-plan re-lists it
                    try:
                        self._retry_transient(
                            lambda: self._fetch_task(
                                pick, tasks[pick], sl, shared, dest_name,
                                dest_store, manifest, version,
                            ),
                            sl.source,
                            unit=tasks[pick].unit,
                        )
                    except (ChecksumError, codec_lib.CodecError):
                        # corrupt bytes OR a torn/misframed wire frame
                        # (CodecError from decode): report, bounded per
                        # unit — if every re-plan keeps rejecting this
                        # unit the data is genuinely bad and the error
                        # propagates. Delta-base staleness never lands
                        # here; the transport handles it internally.
                        u = tasks[pick].unit
                        with shared["lock"]:
                            n = shared["rejects"].get(u, 0) + 1
                            shared["rejects"][u] = n
                        if n > policy.retry_limit:
                            raise
                        if rec.enabled:
                            rec.counter_add(obs.CTR_CORRUPT_REJECTS, 1)
                            rec.event(
                                "corrupt_reject", track=self.worker.worker_id,
                                source=sl.source, unit=u,
                            )
                        self._span_stop(
                            shared, _SourceLost(sl.source, evidence="corrupt")
                        )
                        return
                finally:
                    shared["sem"].release()
                if hedged:
                    continue  # twin may still hold the claim; keep going
        except TransportError as e:
            if dest_store.failed:
                # our own store died (dest preemption), not the source
                self._span_stop(shared, e)
            else:
                self._span_stop(
                    shared,
                    _SourceLost(
                        sl.source,
                        evidence="transient"
                        if getattr(e, "transient", False)
                        else "fatal",
                    ),
                )
        except BaseException as e:  # noqa: BLE001 — relayed to the caller
            self._span_stop(shared, e)

    def _fetch_task(
        self,
        ti: int,
        t: _PullTask,
        sl: SourceSlice,
        shared: dict,
        dest_name: str,
        dest_store: WorkerStore,
        manifest,
        version: int,
    ) -> None:
        unit = manifest.units[t.unit]
        if not codec_lib.get_codec(sl.codec).lossless:
            # decoded bytes won't match the publish-time checksum: mark
            # the unit before any finish check can verify it
            with shared["lock"]:
                shared["lossy_units"].add(t.unit)
        whole = t.offset == 0 and t.nbytes == unit.nbytes
        rec = self.client.recorder
        track = self.worker.worker_id
        lc = _link_class(sl.source, sl.transport)
        started = self.client.clock()
        with shared["lock"]:
            if shared["stop"] is not None or shared["taskdone"][ti]:
                return  # span drained / hedge twin already won
            shared["inflight"][ti] = (started, sl.source)
        sp = None
        if rec.enabled:
            t0 = rec.clock()
            sp = rec.span(
                "pull_unit" if whole else "pull_chunk",
                track=track, source=sl.source, codec=sl.codec,
                unit=unit.name, bytes=t.nbytes, link_class=lc,
            )
        try:
            if whole:
                self.client.transport.pull_unit(
                    sl.source, self.shard_idx, unit, manifest.checksums[t.unit],
                    dest_store, codec=sl.codec, link_class=lc,
                )
            else:
                dbase = None
                if getattr(codec_lib.get_codec(sl.codec), "needs_base", False):
                    # delta chunk: hand the transport the destination's
                    # held bytes for this exact range (the base buffers
                    # stay intact until the reassembled unit is absorbed)
                    held = self.client.transport._dest_base(dest_store, unit)
                    if held is not None and held.nbytes == unit.nbytes:
                        dbase = held[t.offset : t.offset + t.nbytes]
                payload = self.client.transport.read_unit_range(
                    sl.source, self.shard_idx, unit, t.offset, t.nbytes,
                    codec=sl.codec, link_class=lc, dest_base=dbase,
                )
        finally:
            if sp is not None:
                sp.end()
                rec.counter_add(obs.CTR_WIRE, rec.clock() - t0)
            with shared["lock"]:
                cur = shared["inflight"].get(ti)
                if cur is not None and cur[1] == sl.source:
                    del shared["inflight"][ti]
        alldone = False
        with shared["lock"]:
            if shared["stop"] is not None:
                return  # span drained while we were on the wire
            if shared["taskdone"][ti]:
                return  # hedge twin won the race; identical bytes, drop
            shared["taskdone"][ti] = True
            shared["ntaskdone"] += 1
            alldone = shared["ntaskdone"] == len(shared["tasks"])
            shared["durations"].append(self.client.clock() - started)
        if not whole:
            with shared["lock"]:
                buf = shared["staging"].get(t.unit)
                if buf is None:
                    buf = shared["staging"][t.unit] = np.empty(
                        unit.nbytes, dtype=np.uint8
                    )
            asm = (
                rec.span("reassemble", track=track, unit=unit.name, bytes=t.nbytes)
                if rec.enabled
                else None
            )
            buf[t.offset : t.offset + t.nbytes] = payload
            if asm is not None:
                asm.end()
        with shared["lock"]:
            shared["remaining"][t.unit] -= 1
            finished = shared["remaining"][t.unit] == 0
            buf = shared["staging"].pop(t.unit, None) if finished else None
            unit_lossy = t.unit in shared["lossy_units"]
        if not finished:
            if alldone:
                shared["done_ev"].set()
            return
        if buf is not None:  # chunked unit: verify end-to-end, then absorb
            # lossy-coded chunks were each verified over their decoded
            # bytes; the publish-time manifest checksum only applies to
            # raw (bit-exact) reassembly
            expected = 0 if unit_lossy else manifest.checksums[t.unit]
            if self.client.transport.verify_checksums and expected:
                t0 = rec.clock() if rec.enabled else 0.0
                got = checksum_lib.checksum(buf)
                if rec.enabled:
                    rec.counter_add(obs.CTR_VERIFY, rec.clock() - t0)
                    rec.event("verify", track=track, unit=unit.name)
                if got != expected:
                    n_chunks = -(-unit.nbytes // (self.chunk_bytes or unit.nbytes))
                    raise ChecksumError(
                        f"unit {unit.name} reassembled from {n_chunks} "
                        f"chunks: checksum {got:#x} != expected {expected:#x}"
                    )
            dest_store.write_unit(unit, buf)
        advanced = False
        with shared["lock"]:
            if shared["stop"] is None:  # a drained span re-lists the unit
                shared["completed"].add(t.unit)
                while shared["done"] in shared["completed"]:
                    shared["done"] += 1
                    advanced = True
                new_done = shared["done"]
                if advanced:
                    # monotone advance before the server learns; max()
                    # because a hedged span can finish units out of the
                    # order their prefix updates land
                    sp_cur = dest_store.serving_prefix
                    if sp_cur is not None:
                        dest_store.serving_prefix = max(sp_cur, new_done)
        if advanced:
            if rec.enabled:
                rec.event("prefix_advance", track=track, done=new_done)
            with self._cv:
                self._scall(
                    "update_progress",
                    self.model, dest_name, self.shard_idx, version, new_done,
                )
        if alldone:
            shared["done_ev"].set()

    def _pull_resharded_span(
        self,
        assignment: Assignment,
        dest_name: str,
        dest_store: WorkerStore,
        done: int,
        rejects: Optional[Dict[int, int]] = None,
    ) -> int:
        """Cross-layout pull: plan row-grid-aligned interval reads
        against the source layout, fetch them window-parallel, assemble
        each destination unit, publish unit progress. Starts at
        destination unit ``done`` (resume).

        The negotiated wire codec flows through the plan:
        ``reshard_wire_codec`` resolves the assignment's codec to one an
        interval read can carry (delta falls back to its int8 base — no
        held prior version exists at interval granularity), the planner
        widens every read to that codec's quantization row grid, and a
        lossy codec takes the fused path — intervals arrive as undecoded
        wire frames (``decode=False``) and ``ReshardExecutor.
        fused_repack`` dequantizes them straight into the unit payload,
        overlapped against the next unit's in-flight reads. A raw
        negotiation keeps the staged decode+repack path and stays
        bit-exact with the pre-codec planner (zero widening).
        """
        from repro.resharding import ReshardExecutor, layout_from_manifests, plan_shard

        codec = codec_lib.reshard_wire_codec(assignment.codec)
        fused = codec != "raw"
        version = assignment.version
        # our own layout family: checksums are disabled because they would
        # be computed over the *pre-pull* buffer contents; same-layout
        # readers chaining off us skip per-unit verification (zeros).
        local_manifest = dest_store.build_manifest(with_checksums=False)
        with self._cv:
            self._scall(
                "put_manifest",
                self.model, dest_name, self.shard_idx, version, local_manifest
            )
        src_n = assignment.source_shards or self.num_shards
        src_manifests = {
            s: self._wait_src_manifest(version, assignment.source, shard_idx=s)
            for s in range(src_n)
        }
        src_layout = layout_from_manifests(src_manifests, src_n)
        dst_layout = layout_from_manifests(
            {self.shard_idx: local_manifest}, self.num_shards
        )
        plan = plan_shard(
            src_layout,
            dst_layout,
            self.shard_idx,
            num_dest_units=local_manifest.num_units,
            codec=codec,
        )
        executor = ReshardExecutor(
            plan, local_manifest, use_kernel=self.device_repack
        )
        source = assignment.source
        rec = self.client.recorder
        track = self.worker.worker_id
        lc = _link_class(source, assignment.transport)
        policy = self.client.retry_policy
        if rejects is None:
            rejects = {}
        count_lock = threading.Lock()

        def fetch_one(p):
            iv = p.interval
            self._await_source_progress(
                source, version, iv.source_shard, iv.source_unit
            )
            src_unit = src_manifests[iv.source_shard].units[iv.source_unit]
            t0 = rec.clock() if rec.enabled else 0.0
            try:
                payload = self._retry_transient(
                    lambda: self.client.transport.read_unit_range(
                        source, iv.source_shard, src_unit, iv.read_offset,
                        iv.read_nbytes, codec=codec, link_class=lc,
                        decode=not fused,
                    ),
                    source,
                    unit=iv.tensor,
                )
            finally:
                if rec.enabled:
                    rec.counter_add(obs.CTR_WIRE, rec.clock() - t0)
            with count_lock:
                self.intervals_pulled += 1
            return payload

        def start_fetch(placed):
            """Kick off window-parallel interval reads for one
            destination unit; returns a ``join()`` that blocks and
            yields payloads in plan order (or re-raises the first
            worker failure)."""
            results: List[Optional[np.ndarray]] = [None] * len(placed)
            errors: List[BaseException] = []
            cursor = [0]

            def work():
                while True:
                    with count_lock:
                        if errors or cursor[0] >= len(placed):
                            return
                        i = cursor[0]
                        cursor[0] += 1
                    try:
                        results[i] = fetch_one(placed[i])
                    except BaseException as e:  # carried to join()
                        with count_lock:
                            errors.append(e)
                        return

            n = max(1, min(self.window, len(placed)))
            threads = [
                threading.Thread(
                    target=work, daemon=True,
                    name=f"{track}-reshard-fetch-{k}",
                )
                for k in range(n)
            ]
            for t in threads:
                t.start()

            def join():
                for t in threads:
                    t.join()
                if errors:
                    raise errors[0]
                return results

            return join

        batches = list(executor.unit_batches(start_unit=done))
        join = None
        for j, (unit, placed) in enumerate(batches):
            if join is None:
                join = start_fetch(placed)
            try:
                payloads = join()
            except TransportError as e:
                raise _SourceLost(
                    source,
                    evidence="transient"
                    if getattr(e, "transient", False)
                    else "fatal",
                )
            except (ChecksumError, codec_lib.CodecError):
                # corrupt interval from this source: same healing as the
                # unit pipe — report the evidence, bounded per dest unit
                rejects[unit.index] = rejects.get(unit.index, 0) + 1
                if rejects[unit.index] > policy.retry_limit:
                    raise
                if rec.enabled:
                    rec.counter_add(obs.CTR_CORRUPT_REJECTS, 1)
                    rec.event(
                        "corrupt_reject", track=track, source=source,
                        unit=unit.name,
                    )
                raise _SourceLost(source, evidence="corrupt")
            join = None
            if j + 1 < len(batches):
                # overlap: the next unit's reads fly while this unit
                # decodes + repacks (the windowed-flow analogue for the
                # interval plane)
                join = start_fetch(batches[j + 1][1])
            t0 = rec.clock() if rec.enabled else 0.0
            if fused:
                payload = executor.fused_repack(unit.index, payloads)
            else:
                staging = executor.make_staging(unit.index)
                for p, pay in zip(placed, payloads):
                    iv = p.interval
                    staging[
                        p.staging_offset : p.staging_offset + iv.nbytes
                    ] = pay[iv.lead : iv.lead + iv.nbytes]
                payload = executor.repack(unit.index, staging)
            if rec.enabled:
                rec.counter_add(obs.CTR_DECODE, rec.clock() - t0)
            dest_store.write_unit(unit, payload)
            done += 1
            dest_store.serving_prefix = done  # before the server learns
            with self._cv:
                self._scall(
                    "update_progress",
                    self.model, dest_name, self.shard_idx, version, done,
                )
        return done

    def _await_source_progress(
        self, source: str, version: int, src_shard: int, needed: int
    ) -> int:
        """Block until the source shard's progress counter exceeds
        ``needed`` (pipeline replication gating); raises
        :class:`_SourceLost` if the source is evicted meanwhile."""
        with self._cv:
            while True:
                try:
                    avail = self._scall(
                        "shard_progress", self.model, source, version, src_shard
                    )
                except ServerUnavailableError:
                    raise  # dead controller, not a dead source/handle
                except (StaleHandleError, TensorHubError):
                    raise _SourceLost(source)
                if avail > needed:
                    return avail
                self.client._wait(_POLL)

    def _handle_source_failure(
        self, dest_name: str, dead_source: str, evidence: str = "fatal"
    ) -> Assignment:
        """Report a failed source and wait for the server to re-route us.

        ``evidence`` classifies what we saw: ``"fatal"`` evicts the
        source, ``"transient"``/``"corrupt"`` strike-count it toward
        quarantine (the server re-plans around a quarantined source but
        keeps it registered)."""
        with self._cv:
            self._scall(
                "report_transfer_failure",
                self.model, dest_name, dead_source, evidence,
                self.client.clock(),
            )
            while True:
                new = self._scall("get_assignment", self.model, dest_name)
                if new is not None:
                    rec = self.client.recorder
                    if rec.enabled:
                        rec.event(
                            "epoch_bump", track=self.worker.worker_id,
                            epoch=new.epoch, dead_source=dead_source,
                        )
                    return new
                self.client._wait(_POLL)

    # -- offload seeding (4.3.4) -----------------------------------------------------------

    def _spawn_seed_pull(self, version: int) -> None:
        if version in self._seed_threads:
            return
        t = threading.Thread(
            target=self._seed_pull_guarded, args=(version,), daemon=True,
            name=f"{self.worker.worker_id}-seed-v{version}",
        )
        self._seed_threads[version] = t
        t.start()

    def _seed_pull_guarded(self, version: int) -> None:
        """Seed pulls run in a daemon thread with no caller to raise to:
        on failure (e.g. a non-convertible layout surfacing as
        ShardLayoutError mid-plan) fail the twin so the server unwinds
        its in-progress state and source refcounts, instead of leaving a
        forever-IN_PROGRESS seeder that blocks smart skipping."""
        twin = offload_name(self.replica)
        try:
            self._seed_pull(version)
        except TensorHubError as e:
            import logging

            logging.getLogger(__name__).warning(
                "%s: offload seed pull of v%s failed: %s", twin, version, e
            )
            with self._cv:
                try:
                    self._server.fail_replica(self.model, twin, reason=str(e))
                except TensorHubError:
                    pass

    def _seed_pull(self, version: int) -> None:
        """Background cross-DC fetch into a CPU buffer; the accelerator keeps
        computing and a later update() consumes the completed seed locally."""
        twin = offload_name(self.replica)
        # seed buffers mirror our registered shard (same local layout), so
        # the twin can be fed by a cross-layout source and later consumed
        # locally over PCIe without any further conversion
        buffers = {n: np.zeros_like(a) for n, a in self.store.tensors().items()}
        off_store = WorkerStore(f"{self.worker.worker_id}@seed")
        off_store.register(buffers, layout=self.store.layouts)
        self._offload_stores[version] = off_store
        self.client.registry.add(twin, self.shard_idx, off_store)
        with self._cv:
            assignment = None
            while assignment is None:
                assignment = self._scall("get_assignment", self.model, twin)
                if assignment is None:
                    self.client._wait(_POLL)
        self._pull(
            assignment,
            op_id=self._next_off_op(),
            dest_name=twin,
            dest_store=off_store,
            twin=True,
        )
