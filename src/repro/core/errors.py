"""Error types for the Reference-Oriented Storage (ROS) control plane."""

from __future__ import annotations


class TensorHubError(Exception):
    """Base class for all TensorHub errors."""


class VersionUnavailableError(TensorHubError):
    """The requested version has no live replica (and none is retained).

    Per paper (4.5), this is a *graceful* error: under heavy spot churn the
    last replica of a retained version may vanish; the client is expected to
    retry on another version ("a new version will be trained and published
    shortly"), not to crash.
    """


class MutabilityViolationError(TensorHubError):
    """A worker mutated (or re-published) weights while a publish commitment
    was outstanding — a violation of the mutability contract (3.2)."""


class ConsistencyError(TensorHubError):
    """Shards of one model-parallel replica issued mismatching requests.

    SPMD shards must execute an identical sequence of control-plane
    operations; a divergent op kind or argument indicates a framework bug
    and is surfaced loudly rather than being silently serialized.
    """


class NotRegisteredError(TensorHubError):
    """publish()/replicate() called before register()."""


class ShardLayoutError(TensorHubError):
    """Source and destination shard layouts are not convertible.

    Mismatched-but-convertible layouts (same tensors, dtypes and global
    shapes; source slices cover every destination slice) are served by the
    cross-layout resharding engine (``repro.resharding``) — a destination
    shard stripes byte-interval reads across all source shards. This
    error is reserved for genuinely incompatible layouts: missing layout
    descriptors with differing local shapes, disagreeing global shapes or
    dtypes, or uncovered destination bytes.
    """


class StaleHandleError(TensorHubError):
    """Operation on a handle whose replica was evicted (failure/preemption)."""


class ServerUnavailableError(TensorHubError):
    """The reference server did not respond; clients fail over to the
    pre-configured backup (4.5 "Reference Server Failure")."""


class ChecksumError(TensorHubError):
    """End-to-end checksum mismatch after a transfer (4.6)."""


class TransportError(TensorHubError):
    """A data-plane read or write failed.

    ``transient`` carries the evidence class the control plane's failure
    classifier needs: ``False`` (default) means the peer is gone for good
    — a dead store, an unregistered shard — and warrants eviction;
    ``True`` means the read merely flaked (injected gray fault, timed-out
    wire read) and should be retried/strike-counted, never escalated
    straight to a cluster-wide eviction of a possibly healthy replica.
    """

    def __init__(self, message: str = "", *, transient: bool = False) -> None:
        super().__init__(message)
        self.transient = transient
