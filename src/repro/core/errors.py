"""Error types for the Reference-Oriented Storage (ROS) control plane."""

from __future__ import annotations


class TensorHubError(Exception):
    """Base class for all TensorHub errors."""


class VersionUnavailableError(TensorHubError):
    """The requested version has no live replica (and none is retained).

    Per paper (4.5), this is a *graceful* error: under heavy spot churn the
    last replica of a retained version may vanish; the client is expected to
    retry on another version ("a new version will be trained and published
    shortly"), not to crash.
    """


class MutabilityViolationError(TensorHubError):
    """A worker mutated (or re-published) weights while a publish commitment
    was outstanding — a violation of the mutability contract (3.2)."""


class ConsistencyError(TensorHubError):
    """Shards of one model-parallel replica issued mismatching requests.

    SPMD shards must execute an identical sequence of control-plane
    operations; a divergent op kind or argument indicates a framework bug
    and is surfaced loudly rather than being silently serialized.
    """


class NotRegisteredError(TensorHubError):
    """publish()/replicate() called before register()."""


class ShardLayoutError(TensorHubError):
    """Source and destination replicas disagree on shard layout.

    ROS transfers shard i -> shard i; resharding must be done by the
    publisher before publish() (paper 2.1 step 4: weights are resharded
    and converted to inference-ready format *then* transferred).
    """


class StaleHandleError(TensorHubError):
    """Operation on a handle whose replica was evicted (failure/preemption)."""


class ServerUnavailableError(TensorHubError):
    """The reference server did not respond; clients fail over to the
    pre-configured backup (4.5 "Reference Server Failure")."""


class ChecksumError(TensorHubError):
    """End-to-end checksum mismatch after a transfer (4.6)."""
