"""Sharded pytree checkpointing with atomic commit.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json        # treedef, leaf names/shapes/dtypes, metadata
        shard_00000.npz      # leaves, chunked into ~512 MB files
        ...
    <dir>/LATEST             # atomically updated pointer

Writes go to ``step_xxx.tmp`` and are renamed into place, so a crash
mid-save never corrupts the previous checkpoint — the trainer
fault-tolerance story (restart -> restore -> resume the data stream from
the recorded offset).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree.structure(tree)
    named = {}
    for path, leaf in flat:
        name = "/".join(_key(k) for k in path)
        named[name] = np.asarray(leaf)
    return named, treedef


def _key(k: Any) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def save(directory: str, step: int, tree: Any, *, metadata: Optional[Dict] = None) -> str:
    """Atomically write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named, _ = _flatten(tree)
    shards, cur, cur_bytes = [], {}, 0
    for name in sorted(named):
        arr = named[name]
        if cur and cur_bytes + arr.nbytes > _SHARD_BYTES:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[name] = arr
        cur_bytes += arr.nbytes
    if cur:
        shards.append(cur)

    leaf_index = {}
    for i, shard in enumerate(shards):
        fname = f"shard_{i:05d}.npz"
        np.savez(os.path.join(tmp, fname), **{n.replace("/", "|"): a for n, a in shard.items()})
        for n, a in shard.items():
            leaf_index[n] = {"file": fname, "shape": list(a.shape), "dtype": str(a.dtype)}

    manifest = {"step": step, "leaves": leaf_index, "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = tempfile.mktemp(dir=directory)
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.isdir(path):
        return None
    return int(name.split("_")[1])


def restore(directory: str, template: Any, *, step: Optional[int] = None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template``; returns (tree, step,
    metadata)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    cache: Dict[str, Any] = {}

    def load(name: str) -> np.ndarray:
        info = manifest["leaves"][name]
        if info["file"] not in cache:
            cache[info["file"]] = np.load(os.path.join(path, info["file"]))
        return cache[info["file"]][name.replace("/", "|")]

    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree.structure(template)
    leaves = []
    for p, leaf in flat:
        name = "/".join(_key(k) for k in p)
        arr = load(name)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree.unflatten(treedef, leaves), manifest["step"], manifest["metadata"]
