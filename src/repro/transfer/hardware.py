"""Calibrated hardware constants.

Two groups:

* **Cluster/network constants** — from the paper's hardware specification
  (5: 8-GPU nodes, 4x400 Gbps RDMA NICs + 1x200 Gbps VPC NIC, ~48 GB/s
  PCIe) and its measured efficiencies (Fig 7a: TensorHub 22 GB/s, NCCL
  18.8 GB/s, UCX 18.1 GB/s of the 25 GB/s per-shard roofline; 2.3: Ray
  object store 40 GB in 32 s). These drive the event simulator.

* **TPU roofline constants** — the dry-run/roofline targets (v5e-class):
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ClusterHW:
    # -- link capacities (bytes/s) --
    rdma_per_shard: float = 25e9  # 4x400 Gbps / 8 workers
    vpc_per_node: float = 25e9  # 200 Gbps
    pcie: float = 48e9  # 3.3 offload measurement

    # -- protocol efficiencies (fraction of link capacity) --
    tensorhub_rdma_eff: float = 0.92  # calibrates to 22 GB/s incl. overheads
    tensorhub_tcp_eff: float = 0.80
    #: cross-DC TCP per-stream throughput (WAN streams, not NIC-limited):
    #: calibrated to the paper's 10 GB seeding transfer in 2.5 s (5.4)
    tcp_stream_per_shard: float = 4e9
    #: vanilla UCX-over-TCP per-stream throughput: calibrated to the
    #: paper's 7.8 s per 10 GB shard (Fig 12)
    ucx_tcp_stream: float = 1.28e9
    nccl_eff: float = 0.752  # 18.8 / 25 (Fig 7a)
    ucx_eff: float = 0.724  # 18.1 / 25 (Fig 7a)
    object_store_bw: float = 1.25e9  # 40 GB / 32 s (2.3)
    object_store_max_shard: float = 35e9  # Ray OOM-crashes beyond this (5.1.1)

    # -- latencies (seconds) --
    unit_latency: float = 50e-6  # per transfer-unit setup
    control_latency: float = 1e-3  # reference-server RPC (4.6: "a few ms")
    rdma_fail_detect: float = 4.0  # conservative RDMA timeout (5.1.3)
    heartbeat_timeout: float = 2.0

    # -- baseline coordination costs --
    #: Ray-driver RPC fan-out cost per stage barrier (NCCL/UCX paths, 5.2)
    driver_rpc: float = 0.15
    #: per-worker arrival jitter into a global barrier: stall(max over N)
    #: grows ~ jitter_scale * ln(N) (straggler amplification, 2.3/5.2)
    straggler_scale: float = 0.25


@dataclasses.dataclass(frozen=True)
class TpuHW:
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link


CLUSTER = ClusterHW()
TPU = TpuHW()
