"""Deterministic gray-failure injection + the data plane's healing knobs.

The paper's fault model (4.5) is fail-stop: a worker is alive or dead,
detected by heartbeat expiry. Real preemptible fleets fail *gray* —
sources go slow, hang, flake intermittently, or serve corrupt bytes.
This module provides:

* :class:`FaultSpec` / :class:`FaultPlan` — a declarative, seedable
  schedule of gray faults on named replicas (or the controller),
  expressible identically on both data planes. The sim plane replays a
  plan bit-for-bit from the seed (virtual time, per-fault RNG streams);
  the threaded plane arms the same schedule against the wall clock, so
  fault *decisions* are deterministic per draw while their interleaving
  with real threads is not — byte-identity of the result is the
  threaded-plane oracle.
* :class:`RetryPolicy` — the self-healing knobs the client/sim executors
  consult: per-read deadline, bounded exponential-backoff retries for
  transient errors, and the hedged-read straggler threshold.
* :class:`ThreadedFaultInjector` — the threaded-plane arm, hooked into
  ``LocalTransport`` (``before_read`` delay/flake, payload byte-flips).
* :class:`SimFaultInjector` — the sim-plane arm, installed via
  ``SimCluster.install_faults``: crash/slow/hang faults become scheduled
  link-capacity events on the fluid network; flaky/corrupt faults are
  per-flow seeded draws.

Faults address *sources*: a ``slow`` fault degrades the target replica's
NIC links, ``flaky``/``corrupt`` afflict reads served by the target.
``corrupt`` faults flip a byte in the served payload **before** the
destination-side checksum verification, so they exercise the
checksum-reject + re-fetch path; they require verification to be on
(the default) — with verification disabled the flip would propagate.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.transfer.hardware import CLUSTER

FAULT_KINDS = ("crash", "hang", "slow", "flaky", "corrupt", "truncate")

#: reserved target name addressing the reference server rather than a
#: replica (sim plane: a scheduled crash_and_recover)
CONTROLLER = "controller"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    kind
        ``crash``  — fail-stop the target at ``start`` (sim: kill_replica /
        crash_and_recover for the controller).
        ``hang``   — reads from the target block (threaded) / its links
        carry zero bandwidth (sim) for ``duration``.
        ``slow``   — gray degradation: sim links scaled to ``severity`` x
        healthy capacity; threaded reads delayed by ``stall`` seconds.
        ``flaky``  — each read/flow from the target fails with a
        *transient* error with probability ``severity``.
        ``corrupt``— each read from the target is corrupted (byte flip /
        checksum reject) with probability ``severity``.
        ``truncate``— each *codec-framed* read from the target ships a
        torn wire frame (tail dropped) with probability ``severity``;
        the destination's decode fails the frame-size integrity check
        with a ``CodecError`` — the decode-failure healing path, distinct
        from ``corrupt``'s checksum reject. Threaded plane only (the sim
        moves no real frames); raw reads are unaffected.
    target
        Replica name, or :data:`CONTROLLER`.
    start / duration
        Fault window in seconds (virtual time on the sim plane, seconds
        since ``arm()`` on the threaded plane). ``crash`` ignores
        ``duration``.
    severity
        ``slow``: remaining bandwidth fraction. ``flaky``/``corrupt``:
        per-read probability.
    stall
        Threaded-plane ``slow`` only: extra seconds per read (wall-clock
        stand-in for the sim's bandwidth scaling).
    direction
        ``slow``/``hang`` only: which NIC direction degrades on the sim
        plane ("both", "up", or "down").
    """

    kind: str
    target: str
    start: float = 0.0
    duration: float = math.inf
    severity: float = 1.0
    stall: float = 0.0
    direction: str = "both"

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.direction not in ("both", "up", "down"):
            raise ValueError(f"bad fault direction {self.direction!r}")
        if not 0.0 <= self.severity <= 1.0:
            raise ValueError(f"severity must be in [0, 1], got {self.severity}")

    def active(self, now: float) -> bool:
        return self.start <= now < self.start + self.duration


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of faults, replayable bit-for-bit.

    Each fault gets its own RNG stream derived from ``(seed, index)``, so
    adding or removing one fault never perturbs the draws of the others.
    """

    seed: int = 0
    faults: Tuple[FaultSpec, ...] = ()

    def __init__(self, seed: int = 0, faults: Iterable[FaultSpec] = ()) -> None:
        object.__setattr__(self, "seed", seed)
        object.__setattr__(self, "faults", tuple(faults))

    def rng(self, index: int) -> random.Random:
        # string seeds hash via SHA-512: stable across processes, unlike
        # tuple seeds (deprecated, PYTHONHASHSEED-dependent)
        return random.Random(f"{self.seed}/{index}")

    def for_target(self, target: str) -> List[Tuple[int, FaultSpec]]:
        return [(i, f) for i, f in enumerate(self.faults) if f.target == target]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Self-healing knobs consulted by both data-plane executors.

    fail_detect
        Per-read deadline: an in-flight read silent for longer is
        reported to the server as *transient* evidence against its
        source. Defaults to the calibrated RDMA failure-detection
        timeout — the same knob ``benchmarks/micro_failure.py`` measures.
    retry_limit / retry_backoff
        Bounded retries for transient errors, with exponential backoff
        ``retry_backoff * 2**attempt``. Also bounds how many times one
        unit may be checksum-rejected before the error is considered
        genuine bad data and propagates.
    hedge_threshold / hedge_min_samples
        Hedged reads: an idle source worker duplicates the slowest
        in-flight unit of a sibling once its age exceeds
        ``hedge_threshold`` x the median observed fetch time (needs at
        least ``hedge_min_samples`` completed fetches to estimate the
        baseline). Whichever twin finishes first delivers; the loser's
        byte-identical result is discarded.
    """

    fail_detect: float = CLUSTER.rdma_fail_detect
    retry_limit: int = 3
    retry_backoff: float = 0.05
    hedge_threshold: float = 3.0
    hedge_min_samples: int = 3

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based)."""
        return self.retry_backoff * (2.0 ** (attempt - 1))


DEFAULT_RETRY_POLICY = RetryPolicy()


class ThreadedFaultInjector:
    """Threaded-plane fault arm, hooked into ``LocalTransport``.

    ``before_read`` runs at the top of every transport read and applies
    hang (bounded block), slow (sleep), and flaky (transient
    ``TransportError``) faults; ``corrupts``/``flip`` implement byte
    corruption of the served payload ahead of destination-side
    verification. The schedule is armed against a wall-clock origin
    (:meth:`arm`); :meth:`release` permanently unblocks hangs so tests
    and benchmarks can drain hung reader threads deterministically.
    """

    _TICK = 0.005  # hang-block granularity: bounded, interruptible sleep

    def __init__(self, plan: FaultPlan, *, clock=time.monotonic) -> None:
        self.plan = plan
        self.clock = clock
        self._t0: Optional[float] = None
        self._released = False
        self._rngs: Dict[int, random.Random] = {
            i: plan.rng(i) for i, _ in enumerate(plan.faults)
        }
        self._by_target: Dict[str, List[Tuple[int, FaultSpec]]] = {}
        for i, spec in enumerate(plan.faults):
            self._by_target.setdefault(spec.target, []).append((i, spec))

    # -- lifecycle ------------------------------------------------------------

    def arm(self) -> "ThreadedFaultInjector":
        """Start the schedule clock (idempotent)."""
        if self._t0 is None:
            self._t0 = self.clock()
        return self

    def release(self) -> None:
        """Permanently unblock hang faults (lets hung reads drain)."""
        self._released = True

    @property
    def now(self) -> float:
        if self._t0 is None:
            self.arm()
        return self.clock() - self._t0

    # -- transport hooks ------------------------------------------------------

    def _active(self, kind: str, target: str) -> Optional[Tuple[int, FaultSpec]]:
        now = self.now
        for i, spec in self._by_target.get(target, ()):
            if spec.kind == kind and spec.active(now):
                return i, spec
        return None

    def before_read(self, replica: str, shard_idx: int) -> None:
        """Apply hang/slow/flaky faults ahead of a read from ``replica``."""
        from repro.core.errors import TransportError

        hit = self._active("hang", replica)
        if hit is not None:
            _, spec = hit
            while not self._released and spec.active(self.now):
                time.sleep(self._TICK)
        hit = self._active("slow", replica)
        if hit is not None and hit[1].stall > 0.0:
            time.sleep(hit[1].stall)
        hit = self._active("flaky", replica)
        if hit is not None:
            i, spec = hit
            if self._rngs[i].random() < spec.severity:
                raise TransportError(
                    f"injected flaky read from {replica}", transient=True
                )

    def corrupts(self, replica: str) -> bool:
        """Draw whether the current read from ``replica`` is corrupted."""
        hit = self._active("corrupt", replica)
        if hit is None:
            return False
        i, spec = hit
        return self._rngs[i].random() < spec.severity

    def truncates(self, replica: str) -> bool:
        """Draw whether the current codec-framed read from ``replica``
        ships a torn (tail-truncated) wire frame."""
        hit = self._active("truncate", replica)
        if hit is None:
            return False
        i, spec = hit
        return self._rngs[i].random() < spec.severity

    def flip(self, payload) -> None:
        """Flip one byte of ``payload`` (a writable ndarray) in place."""
        flat = payload.reshape(-1).view("u1")
        if flat.size == 0:
            return
        # deterministic position per plan seed; independent of draw RNGs
        idx = random.Random(f"{self.plan.seed}/flip/{int(flat.size)}").randrange(
            flat.size
        )
        flat[idx] ^= 0xFF

    def controller_crashes(self) -> List[float]:
        """Scheduled controller-crash times (applied by the harness: the
        threaded plane's controller crash is ``ReferenceServer.crash()``
        + ``failover.recover``, driven from test/benchmark code)."""
        return sorted(
            f.start for _, f in self._by_target.get(CONTROLLER, ())
            if f.kind == "crash"
        )


class SimFaultInjector:
    """Sim-plane fault arm: schedules a :class:`FaultPlan` as virtual-time
    events on a ``SimCluster``.

    crash  -> ``cluster.kill_replica`` (controller: ``crash_and_recover``)
    slow   -> target's up/down RDMA links scaled to ``severity`` for the
              window, then restored (``hang`` is ``slow`` at 0.0 — the
              max-min allocator gives flows on a zero-capacity link rate
              zero, and they resume when capacity returns)
    flaky  -> a seeded draw per flow creation; a hit schedules a
              *transient* kill of that flow shortly after it starts
    corrupt-> a seeded draw per completed flow; the sim moves no real
              bytes, so a hit manifests as a checksum reject at delivery
    """

    def __init__(self, cluster, plan: FaultPlan) -> None:
        self.cluster = cluster
        self.plan = plan
        #: schedule origin: fault windows are relative to installation
        #: time, mirroring the threaded injector's ``arm()`` clock origin
        #: (a plan can be armed mid-run, after a healthy warm-up phase)
        self.origin = float(cluster.env.now)
        self._rngs: Dict[int, random.Random] = {
            i: plan.rng(i) for i, _ in enumerate(plan.faults)
        }
        self._by_target: Dict[str, List[Tuple[int, FaultSpec]]] = {}
        for i, spec in enumerate(plan.faults):
            self._by_target.setdefault(spec.target, []).append((i, spec))
        self._base_capacity: Dict[str, float] = {}

    def install(self) -> None:
        env = self.cluster.env
        for i, spec in enumerate(self.plan.faults):
            if spec.target == CONTROLLER:
                if spec.kind == "crash":
                    env.schedule(spec.start, self.cluster.crash_and_recover)
                continue
            if spec.kind == "crash":
                env.schedule(
                    spec.start,
                    lambda t=spec.target: self.cluster.kill_replica(t),
                )
            elif spec.kind in ("slow", "hang"):
                factor = 0.0 if spec.kind == "hang" else spec.severity
                env.schedule(
                    spec.start, lambda s=spec, f=factor: self._scale(s, f)
                )
                if math.isfinite(spec.duration):
                    env.schedule(
                        spec.start + spec.duration,
                        lambda s=spec: self._scale(s, 1.0),
                    )
            # flaky/corrupt are queried at flow boundaries, not scheduled

    def _scale(self, spec: FaultSpec, factor: float) -> None:
        """Scale the target replica's NIC links; 1.0 restores healthy."""
        net = self.cluster.net
        net._advance_to_now()
        for (rep, _idx), w in self.cluster._workers.items():
            if rep != spec.target:
                continue
            links = {"both": (w.up, w.down), "up": (w.up,), "down": (w.down,)}[
                spec.direction
            ]
            for lk in links:
                base = self._base_capacity.setdefault(lk.name, lk.capacity)
                lk.capacity = base * factor
        net._reallocate()

    def _hit(self, kind: str, replica: str, now: float) -> bool:
        for i, spec in self._by_target.get(replica, ()):
            if spec.kind == kind and spec.active(now - self.origin):
                if self._rngs[i].random() < spec.severity:
                    return True
        return False

    def flaky_hit(self, replica: str, now: float) -> bool:
        return self._hit("flaky", replica, now)

    def corrupt_hit(self, replica: str, now: float) -> bool:
        return self._hit("corrupt", replica, now)
