"""Simulated TensorHub cluster: real control plane, fluid data plane.

The *same* :class:`repro.core.server.ReferenceServer` used by the threaded
client is driven here by generator processes over the discrete-event
network (``simnet``). Weight bytes are represented by sizes only; progress
counters, transactions, retention, scheduling and failure handling are the
real production code paths.

This module is what the benchmark harness (one module per paper figure)
builds on, together with the calibrated baselines at the bottom (NCCL /
UCX / object-store models, 2.3 + 5).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, Generator, List, Optional, Sequence, Tuple

from repro.core.errors import StaleHandleError, TensorHubError
from repro.core.meta import ShardManifest, TensorMeta, TransferUnit, WorkerInfo
from repro.core.server import Assignment, ReferenceServer, offload_name
from repro.transfer.hardware import CLUSTER, ClusterHW
from repro.transfer.simnet import FlowKilled, Link, SimEnv, SimEvent, SimNetwork


class PreemptedError(Exception):
    """The worker itself was killed; its process stops executing (a real
    preempted worker sends nothing further — in particular it must NOT
    report its own source as failed)."""


class _SimSourceLost(Exception):
    """Internal: assigned source died mid-pull; re-route and resume."""

    def __init__(self, source: str) -> None:
        super().__init__(source)
        self.source = source


def make_manifest(unit_bytes: Sequence[int]) -> ShardManifest:
    """Size-only manifest (the simulator moves no real bytes)."""
    tensors = tuple(
        TensorMeta(name=f"t{i}", shape=(n,), dtype="uint8", nbytes=int(n))
        for i, n in enumerate(unit_bytes)
    )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=int(n))
        for i, n in enumerate(unit_bytes)
    )
    return ShardManifest(tensors=tensors, units=units, checksums=(0,) * len(units))


def make_layout_manifests(
    global_unit_bytes: Sequence[int], num_shards: int
) -> List[ShardManifest]:
    """Per-shard manifests with layout descriptors: each global transfer
    unit is a 1-D byte tensor sliced contiguously across ``num_shards``
    (the remainder rides on the last shard). Replicas built from the same
    ``global_unit_bytes`` with *different* shard counts are convertible —
    the resharding planner stripes reads across their shards."""
    out: List[ShardManifest] = []
    for shard in range(num_shards):
        tensors: List[TensorMeta] = []
        units: List[TransferUnit] = []
        for k, g in enumerate(global_unit_bytes):
            g = int(g)
            per = g // num_shards
            start = shard * per
            stop = g if shard == num_shards - 1 else start + per
            n = stop - start
            tensors.append(
                TensorMeta(
                    name=f"t{k}",
                    shape=(n,),
                    dtype="uint8",
                    nbytes=n,
                    global_shape=(g,),
                    offset=(start,),
                )
            )
            units.append(TransferUnit(index=k, name=f"t{k}", nbytes=n))
        out.append(
            ShardManifest(
                tensors=tuple(tensors),
                units=tuple(units),
                checksums=(0,) * len(units),
            )
        )
    return out


@dataclasses.dataclass
class SimWorker:
    """One shard-owning worker: a GPU with its NIC slice and PCIe lane."""

    worker_id: str
    node: str
    datacenter: str
    up: Link
    down: Link
    pcie: Link
    vpc_up: Link
    vpc_down: Link
    is_spot: bool = False
    alive: bool = True
    total_stall: float = 0.0
    _stall_since: Optional[float] = None

    def stall_begin(self, now: float) -> None:
        if self._stall_since is None:
            self._stall_since = now

    def stall_end(self, now: float) -> None:
        if self._stall_since is not None:
            self.total_stall += now - self._stall_since
            self._stall_since = None


class SimCluster:
    """Topology + server + process plumbing."""

    def __init__(
        self,
        *,
        hw: ClusterHW = CLUSTER,
        pipeline_replication: bool = True,
        smart_skipping: bool = True,
        control_latency: Optional[float] = None,
        tcp_compression: float = 1.0,
    ) -> None:
        #: cross-DC wire-byte multiplier: int8 quantization (kernels/quant)
        #: moves q(int8) + per-1024 f32 scales = x0.2539 of bf16 bytes at
        #: <1% relative error (beyond-paper; EXPERIMENTS.md Perf)
        self.tcp_compression = tcp_compression
        self.env = SimEnv()
        self.net = SimNetwork(self.env)
        self.hw = hw
        self.control_latency = (
            hw.control_latency if control_latency is None else control_latency
        )
        self.server = ReferenceServer(
            heartbeat_timeout=hw.heartbeat_timeout,
            pipeline_replication=pipeline_replication,
            smart_skipping=smart_skipping,
        )
        self.server.add_watcher(self.env.state_notify)
        self._workers: Dict[Tuple[str, int], SimWorker] = {}
        self._node_seq = itertools.count()
        self.replicas: Dict[str, "SimReplica"] = {}

    # -- topology -----------------------------------------------------------------

    def _make_worker(
        self, replica: str, shard_idx: int, datacenter: str, node: str, is_spot: bool
    ) -> SimWorker:
        hw = self.hw
        wid = f"{replica}/shard{shard_idx}"
        w = SimWorker(
            worker_id=wid,
            node=node,
            datacenter=datacenter,
            up=self.net.link(f"{node}/{wid}:up", hw.rdma_per_shard),
            down=self.net.link(f"{node}/{wid}:down", hw.rdma_per_shard),
            pcie=self.net.link(f"{node}/{wid}:pcie", hw.pcie),
            vpc_up=self.net.link(f"{node}:vpc_up", hw.vpc_per_node),
            vpc_down=self.net.link(f"{node}:vpc_down", hw.vpc_per_node),
            is_spot=is_spot,
        )
        self._workers[(replica, shard_idx)] = w
        return w

    def worker(self, replica: str, shard_idx: int) -> SimWorker:
        # offload twins live on the origin replica's nodes (CPU memory)
        key = (replica, shard_idx)
        if key not in self._workers and replica.endswith("@offload"):
            origin = replica[: -len("@offload")]
            return self._workers[(origin, shard_idx)]
        return self._workers[key]

    def add_replica(
        self,
        model: str,
        name: str,
        num_shards: int,
        *,
        datacenter: str = "dc0",
        nodes: Optional[Sequence[str]] = None,
        shards_per_node: int = 8,
        is_spot: bool = False,
        retain: Optional[object] = None,
        offload_seeding: bool = False,
        unit_bytes: Sequence[int] = (),
        global_unit_bytes: Optional[Sequence[int]] = None,
    ) -> "SimReplica":
        """``unit_bytes`` sizes one shard's units directly (same-layout
        replicas only); ``global_unit_bytes`` instead sizes the *global*
        model's units and slices them over ``num_shards`` — replicas
        created from the same global sizes with different shard counts
        reshard into each other."""
        rep = SimReplica(
            cluster=self,
            model=model,
            name=name,
            num_shards=num_shards,
            datacenter=datacenter,
            nodes=nodes,
            shards_per_node=shards_per_node,
            is_spot=is_spot,
            retain=retain,
            offload_seeding=offload_seeding,
            unit_bytes=list(unit_bytes),
            global_unit_bytes=(
                None if global_unit_bytes is None else list(global_unit_bytes)
            ),
        )
        self.replicas[name] = rep
        return rep

    # -- failure injection ------------------------------------------------------------

    def kill_replica(self, name: str) -> None:
        """Spot preemption / node failure: immediate, no grace (5.3)."""
        rep = self.replicas.get(name)
        if rep is not None:
            for s in rep.shards:
                s.worker.alive = False
                s.dead = True
        # flows from/to the victim die; readers notice after the RDMA timeout
        self.net.kill_flows(
            lambda f: f.tag.startswith(f"{name}/") or f"->{name}/" in f.tag,
            notice_delay=self.hw.rdma_fail_detect,
        )
        # the server learns via missed heartbeats
        self.env.schedule(self.hw.heartbeat_timeout, lambda: self._server_fail(name))
        self._notify_progress_keys(name)

    def _server_fail(self, name: str) -> None:
        for model in list(self.server._models):  # noqa: SLF001 — harness hook
            try:
                self.server.fail_replica(model, name, reason="heartbeat timeout")
            except TensorHubError:
                pass
        self._notify_progress_keys(name)

    def _notify_progress_keys(self, name: str) -> None:
        rep = self.replicas.get(name)
        n = rep.num_shards if rep is not None else 64
        for i in range(n):
            self.env.key_notify(("progress", name, i))
            self.env.key_notify(("progress", offload_name(name), i))

    # -- metrics -------------------------------------------------------------------------

    def total_stall(self, replicas: Optional[Sequence[str]] = None) -> float:
        names = self.replicas.keys() if replicas is None else replicas
        return sum(
            s.worker.total_stall for n in names for s in self.replicas[n].shards
        )

    def per_worker_stalls(self, replicas: Sequence[str]) -> List[float]:
        return [s.worker.total_stall for n in replicas for s in self.replicas[n].shards]

    def run(self, until: float = math.inf) -> float:
        return self.env.run(until)


class SimShard:
    """Generator-based mirror of ``repro.core.client.ShardHandle``."""

    def __init__(self, replica: "SimReplica", shard_idx: int, worker: SimWorker) -> None:
        self.rep = replica
        self.idx = shard_idx
        self.worker = worker
        self.dead = False
        self._op = itertools.count()
        self._off_op = itertools.count(1_000_000)
        self._seeding: set = set()

    # plumbing ------------------------------------------------------------------

    @property
    def env(self) -> SimEnv:
        return self.rep.cluster.env

    @property
    def server(self) -> ReferenceServer:
        return self.rep.cluster.server

    @property
    def hw(self) -> ClusterHW:
        return self.rep.cluster.hw

    def _ctrl(self) -> SimEvent:
        return self.env.timeout(self.rep.cluster.control_latency)

    # Table-2 ops (generators) -----------------------------------------------------

    def g_open(self) -> Generator:
        info = WorkerInfo(
            worker_id=self.worker.worker_id,
            node=self.worker.node,
            datacenter=self.worker.datacenter,
            is_spot=self.worker.is_spot,
        )
        yield self._ctrl()
        self.server.open(
            self.rep.model,
            self.rep.name,
            self.rep.num_shards,
            self.idx,
            worker=info,
            retain=self.rep.retain,
        )
        self.server.register(self.rep.model, self.rep.name, self.idx)

    def g_publish(self, version: int) -> Generator:
        yield self._ctrl()
        self.server.publish(
            self.rep.model,
            self.rep.name,
            self.idx,
            version,
            self.rep.manifest_for(self.idx),
            op_id=next(self._op),
        )
        self.env.key_notify(("progress", self.rep.name, self.idx))

    def g_unpublish(self) -> Generator:
        yield self._ctrl()
        res = self.server.unpublish(
            self.rep.model, self.rep.name, self.idx, op_id=next(self._op)
        )
        if res.offload_required and res.offload_version is not None:
            yield from self._g_offload_copy(res.offload_version)
        yield from self._g_wait_drained()

    def g_replicate(self, spec, *, stall: bool = True) -> Generator:
        if stall:
            self.worker.stall_begin(self.env.now)
        op = next(self._op)
        yield self._ctrl()
        assignment = self.server.begin_replicate(
            self.rep.model, self.rep.name, self.idx, spec, op_id=op
        )
        while assignment is None:
            yield self.env.state_wait()
            assignment = self.server.redeem(self.rep.model, self.rep.name, op_id=op)
        yield from self._g_pull(assignment, dest=self.rep.name)
        if stall:
            self.worker.stall_end(self.env.now)
        return assignment.version

    def g_update(self, spec="latest", *, stall: bool = True) -> Generator:
        """One update() poll; returns True if the weights changed."""
        op = next(self._op)
        yield self._ctrl()
        d = self.server.begin_update(
            self.rep.model,
            self.rep.name,
            self.idx,
            spec,
            op_id=op,
            offload_seeding=self.rep.offload_seeding,
        )
        if d.seed_started and d.seed_version is not None:
            if d.seed_version not in self._seeding:
                self._seeding.add(d.seed_version)
                self.env.process(self._g_seed_pull(d.seed_version))
        if not d.updated:
            return False
        if stall:
            self.worker.stall_begin(self.env.now)
        if d.offload_required and d.offload_version is not None:
            yield from self._g_offload_copy(d.offload_version)
        yield from self._g_wait_drained()
        assert d.assignment is not None
        yield from self._g_pull(d.assignment, dest=self.rep.name)
        if stall:
            self.worker.stall_end(self.env.now)
        return True

    # internals ---------------------------------------------------------------------

    def _g_wait_drained(self) -> Generator:
        while not self.server.finish_unpublish(self.rep.model, self.rep.name):
            yield self.env.state_wait()

    def _g_offload_copy(self, version: int) -> Generator:
        """Retention offload: GPU -> CPU over PCIe, then publish_offload."""
        nbytes = self.rep.manifest_for(self.idx).total_bytes
        yield self.rep.cluster.net.flow(
            nbytes, [self.worker.pcie], tag=f"{self.rep.name}/s{self.idx}:offload"
        )
        yield self._ctrl()
        self.server.publish_offload(
            self.rep.model,
            self.rep.name,
            self.idx,
            version,
            self.rep.manifest_for(self.idx),
            op_id=next(self._op),
        )
        self.env.key_notify(("progress", offload_name(self.rep.name), self.idx))

    def _flow_for_bytes(
        self,
        src_replica: str,
        src_shard: int,
        nbytes: float,
        transport: str,
        dest_name: str,
    ) -> SimEvent:
        cluster = self.rep.cluster
        src_w = cluster.worker(src_replica, src_shard)
        dst_w = self.worker
        hw = self.hw
        if src_w.node == dst_w.node:
            links = [dst_w.pcie]  # local CPU<->GPU consumption (seed twins)
            cap = hw.pcie
        elif transport == "tcp":
            links = [src_w.vpc_up, dst_w.vpc_down]
            # WAN TCP streams are stream-limited before they are NIC-limited
            cap = min(hw.tensorhub_tcp_eff * hw.vpc_per_node, hw.tcp_stream_per_shard)
        else:
            links = [src_w.up, dst_w.down]
            cap = hw.tensorhub_rdma_eff * hw.rdma_per_shard
        if transport == "tcp" and cluster.tcp_compression < 1.0:
            nbytes = nbytes * cluster.tcp_compression
        tag = f"{src_replica}/s{src_shard}->{dest_name}/s{self.idx}"
        return cluster.net.flow(
            nbytes, links, rate_cap=cap, latency=hw.unit_latency, tag=tag
        )

    def _g_pull(self, assignment: Assignment, *, dest: str) -> Generator:
        """The pipeline-replication read loop (4.3.3) in virtual time.

        Dispatches per assignment: same-layout sources stream whole units
        shard-to-shard; a source with a different shard count runs the
        resharding plan (striped interval flows from *all* source shards).
        Progress counts completed destination units either way, so a
        re-route mid-transfer may switch modes and resume (4.5).
        """
        version = assignment.version
        while True:
            try:
                if assignment.resharded:
                    yield from self._g_pull_resharded(assignment, dest)
                else:
                    yield from self._g_pull_units(assignment, dest)
                break
            except _SimSourceLost as e:
                assignment = yield from self._g_reroute(dest, e.source)
        yield self._ctrl()
        self.server.complete_replicate(
            self.rep.model,
            dest,
            self.idx,
            version,
            op_id=next(self._off_op) if dest != self.rep.name else next(self._op),
        )

    def _g_await_source_unit(
        self, source: str, version: int, src_shard: int, needed: int
    ) -> Generator:
        """Wait until the source shard's progress counter exceeds
        ``needed``; keyed wakeups with a periodic re-check safety net."""
        env = self.env
        while True:
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            try:
                avail = self.server.shard_progress(
                    self.rep.model, source, version, src_shard
                )
            except (StaleHandleError, TensorHubError):
                raise _SimSourceLost(source)
            if avail > needed:
                return avail
            yield env.any_of(
                env.key_wait(("progress", source, src_shard)), env.timeout(0.5)
            )

    def _g_pull_units(self, assignment: Assignment, dest: str) -> Generator:
        env = self.env
        version = assignment.version
        manifest = self.rep.manifest_for(self.idx)
        units = manifest.units
        source = assignment.source
        transport = assignment.transport
        done = self.server.shard_progress(self.rep.model, dest, version, self.idx)
        while done < len(units):
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            avail = yield from self._g_await_source_unit(
                source, version, self.idx, done
            )
            for i in range(done, avail):
                try:
                    yield self._flow_for_bytes(
                        source, self.idx, units[i].nbytes, transport, dest
                    )
                except FlowKilled:
                    if self.dead:
                        raise PreemptedError(self.worker.worker_id)
                    raise _SimSourceLost(source)
                done += 1
                self.server.update_progress(
                    self.rep.model, dest, self.idx, version, done
                )
                env.key_notify(("progress", dest, self.idx))

    def _g_pull_resharded(self, assignment: Assignment, dest: str) -> Generator:
        """Striped cross-layout pull in virtual time: real planner, fluid
        bytes. Each interval flows over the *owning* source shard's NIC,
        so bandwidth aggregates across all source shards exactly as the
        byte accounting says it should."""
        from repro.resharding import layout_from_manifests, plan_shard

        env = self.env
        version = assignment.version
        src_n = assignment.source_shards
        local_manifest = self.rep.manifest_for(self.idx)
        self.server.put_manifest(
            self.rep.model, dest, self.idx, version, local_manifest
        )
        source = assignment.source
        src_manifests = {}
        for s in range(src_n):
            while True:
                m = self.server.replica_manifest(self.rep.model, version, source, s)
                if m is not None:
                    break
                yield env.state_wait()
                if self.dead:
                    raise PreemptedError(self.worker.worker_id)
            src_manifests[s] = m
        src_layout = layout_from_manifests(src_manifests, src_n)
        dst_layout = layout_from_manifests(
            {self.idx: local_manifest}, self.rep.num_shards
        )
        plan = plan_shard(
            src_layout,
            dst_layout,
            self.idx,
            num_dest_units=local_manifest.num_units,
        )
        by_unit = plan.intervals_by_unit()
        transport = assignment.transport
        done = self.server.shard_progress(self.rep.model, dest, version, self.idx)
        for unit in local_manifest.units[done:]:
            for iv in by_unit.get(unit.index, []):
                yield from self._g_await_source_unit(
                    source, version, iv.source_shard, iv.source_unit
                )
                try:
                    yield self._flow_for_bytes(
                        source, iv.source_shard, iv.nbytes, transport, dest
                    )
                except FlowKilled:
                    if self.dead:
                        raise PreemptedError(self.worker.worker_id)
                    raise _SimSourceLost(source)
            done += 1
            self.server.update_progress(self.rep.model, dest, self.idx, version, done)
            env.key_notify(("progress", dest, self.idx))

    def _g_reroute(self, dest: str, dead_source: str) -> Generator:
        if self.dead:
            raise PreemptedError(self.worker.worker_id)
        yield self._ctrl()
        self.server.report_transfer_failure(self.rep.model, dest, dead_source)
        while True:
            new = self.server.get_assignment(self.rep.model, dest)
            if new is not None:
                return new
            yield self.env.state_wait()
            if self.dead:
                raise PreemptedError(self.worker.worker_id)

    def _g_seed_pull(self, version: int) -> Generator:
        """Background cross-DC fetch into CPU memory (offload seeding,
        4.3.4) — does NOT count as GPU stall."""
        twin = offload_name(self.rep.name)
        while True:
            assignment = self.server.get_assignment(self.rep.model, twin)
            if assignment is not None:
                break
            yield self.env.state_wait()
        yield from self._g_pull(assignment, dest=twin)


class SimReplica:
    """A model-parallel group of SimShards."""

    def __init__(
        self,
        *,
        cluster: SimCluster,
        model: str,
        name: str,
        num_shards: int,
        datacenter: str,
        nodes: Optional[Sequence[str]],
        shards_per_node: int,
        is_spot: bool,
        retain: Optional[object],
        offload_seeding: bool,
        unit_bytes: List[int],
        global_unit_bytes: Optional[List[int]] = None,
    ) -> None:
        self.cluster = cluster
        self.model = model
        self.name = name
        self.num_shards = num_shards
        self.datacenter = datacenter
        self.is_spot = is_spot
        self.retain = retain
        self.offload_seeding = offload_seeding
        self.unit_bytes = unit_bytes
        self.global_unit_bytes = global_unit_bytes
        if global_unit_bytes is not None:
            self.manifests = make_layout_manifests(global_unit_bytes, num_shards)
        else:
            self.manifests = [make_manifest(unit_bytes)] * num_shards
        self.manifest = self.manifests[0]
        self.shard_bytes = self.manifests[0].total_bytes
        self.shards: List[SimShard] = []
        for i in range(num_shards):
            node = (
                nodes[i // shards_per_node]
                if nodes is not None
                else f"{datacenter}/{name}-n{i // shards_per_node}"
            )
            w = cluster._make_worker(name, i, datacenter, node, is_spot)
            self.shards.append(SimShard(self, i, w))

    def manifest_for(self, shard_idx: int) -> ShardManifest:
        return self.manifests[shard_idx]

    # -- group-level helpers: run an op on every shard, fire when all done ------------

    def _all(self, gens: List[Generator]) -> SimEvent:
        """Start one process per shard; the returned event fires (with the
        list of per-shard results) when all of them finished. A failing
        shard fails the group event."""
        env = self.cluster.env
        done = SimEvent(env)
        remaining = len(gens)
        results: List[object] = [None] * len(gens)

        def on_finish(i: int) -> Callable[[SimEvent], None]:
            def cb(ev: SimEvent) -> None:
                nonlocal remaining
                if ev.error is not None:
                    done.fail(ev.error)
                    return
                results[i] = ev.value
                remaining -= 1
                if remaining == 0:
                    done.succeed(results)

            return cb

        for i, g in enumerate(gens):
            env.process(g).add_callback(on_finish(i))
        return done

    def open(self) -> SimEvent:
        return self._all([s.g_open() for s in self.shards])

    def publish(self, version: int) -> SimEvent:
        return self._all([s.g_publish(version) for s in self.shards])

    def unpublish(self) -> SimEvent:
        return self._all([s.g_unpublish() for s in self.shards])

    def replicate(self, spec="latest", *, stall: bool = True) -> SimEvent:
        return self._all([s.g_replicate(spec, stall=stall) for s in self.shards])

    def update(self, spec="latest", *, stall: bool = True) -> SimEvent:
        return self._all([s.g_update(spec, stall=stall) for s in self.shards])
