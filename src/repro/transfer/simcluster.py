"""Simulated TensorHub cluster: real control plane, fluid data plane.

The *same* :class:`repro.core.server.ReferenceServer` used by the threaded
client is driven here by generator processes over the discrete-event
network (``simnet``). Weight bytes are represented by sizes only; progress
counters, transactions, retention, scheduling and failure handling are the
real production code paths.

This module is what the benchmark harness (one module per paper figure)
builds on, together with the calibrated baselines at the bottom (NCCL /
UCX / object-store models, 2.3 + 5).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import math
import warnings
import weakref
from typing import Callable, Dict, Generator, List, Optional, Sequence, Tuple

from repro.core import failover as failover_lib
from repro.core.errors import StaleHandleError, TensorHubError
from repro.core.meta import (
    ShardManifest,
    TensorMeta,
    TransferUnit,
    WorkerInfo,
    dtype_from_str,
)
from repro.core.oplog import OpLog
from repro.core.server import Assignment, ReferenceServer, SourceSlice, offload_name
from repro.obs import telemetry as obs
from repro.transfer import codec as codec_lib
from repro.transfer.engine import DEFAULT_CHUNK_BYTES, DEFAULT_WINDOW
from repro.transfer.faults import (
    DEFAULT_RETRY_POLICY,
    FaultPlan,
    RetryPolicy,
    SimFaultInjector,
)
from repro.transfer.hardware import CLUSTER, TPU, ClusterHW
from repro.transfer.simnet import FlowKilled, Link, SimEnv, SimEvent, SimNetwork


class PreemptedError(Exception):
    """The worker itself was killed; its process stops executing (a real
    preempted worker sends nothing further — in particular it must NOT
    report its own source as failed)."""


class _SimSourceLost(Exception):
    """Internal: assigned source failed us mid-pull; re-route and resume.
    ``evidence`` mirrors the threaded client's classes ("fatal" |
    "transient" | "corrupt") and is forwarded to
    ``report_transfer_failure`` for strike-counting vs eviction."""

    def __init__(self, source: str, evidence: str = "fatal") -> None:
        super().__init__(source)
        self.source = source
        self.evidence = evidence


class _SimReplan(Exception):
    """Internal: the server re-partitioned our plan (work stealing or
    re-routing); re-fetch the assignment and resume from the prefix."""


#: one data-plane fetch: a whole transfer unit, or a byte sub-range of
#: one; ``owner`` is the index of the plan slice the server's partition
#: assigned it to (a load hint — any same-layout source may execute it)
_Task = collections.namedtuple("_Task", "unit offset nbytes owner")


class _SimSlots:
    """Counting semaphore over SimEvents: caps in-flight flows per shard."""

    def __init__(self, env: SimEnv, slots: int) -> None:
        self.env = env
        self.free = slots
        self._waiters: collections.deque = collections.deque()

    def acquire(self) -> SimEvent:
        ev = SimEvent(self.env)
        if self.free > 0:
            self.free -= 1
            ev.succeed()
        else:
            self._waiters.append(ev)
        return ev

    def release(self) -> None:
        if self._waiters:
            self._waiters.popleft().succeed()
        else:
            self.free += 1


def _sim_dtype(nbytes: int, dtype: str) -> Tuple[str, int]:
    """``(dtype, itemsize)`` for one size-only sim tensor: the requested
    element dtype when the byte count holds whole elements, else a uint8
    fallback (so odd sizes stay representable)."""
    if dtype != "uint8":
        isz = int(dtype_from_str(dtype).itemsize)
        if nbytes % isz == 0:
            return dtype, isz
    return "uint8", 1


def make_manifest(
    unit_bytes: Sequence[int], dtype: str = "uint8"
) -> ShardManifest:
    """Size-only manifest (the simulator moves no real bytes).

    ``dtype`` is the declared element type: the sim cluster passes its
    ``codec_dtype`` so server-side codec negotiation sees the same
    quantizable payload the fluid byte accounting assumes (a size-only
    uint8 stand-in would read as unquantizable and degrade to raw)."""
    tensors = []
    for i, n in enumerate(unit_bytes):
        n = int(n)
        dt, isz = _sim_dtype(n, dtype)
        tensors.append(
            TensorMeta(name=f"t{i}", shape=(n // isz,), dtype=dt, nbytes=n)
        )
    units = tuple(
        TransferUnit(index=i, name=f"t{i}", nbytes=int(n))
        for i, n in enumerate(unit_bytes)
    )
    return ShardManifest(
        tensors=tuple(tensors), units=units, checksums=(0,) * len(units)
    )


def make_layout_manifests(
    global_unit_bytes: Sequence[int], num_shards: int, dtype: str = "uint8"
) -> List[ShardManifest]:
    """Per-shard manifests with layout descriptors: each global transfer
    unit is a 1-D tensor sliced contiguously across ``num_shards``
    (the remainder rides on the last shard). Replicas built from the same
    ``global_unit_bytes`` with *different* shard counts are convertible —
    the resharding planner stripes reads across their shards.

    With a non-uint8 ``dtype`` the slicing happens in element space
    (shard boundaries stay element-aligned) so negotiation and the
    row-grid planner see a quantizable payload; a global size that does
    not hold whole elements falls back to uint8 for that tensor."""
    out: List[ShardManifest] = []
    for shard in range(num_shards):
        tensors: List[TensorMeta] = []
        units: List[TransferUnit] = []
        for k, g in enumerate(global_unit_bytes):
            g = int(g)
            dt, isz = _sim_dtype(g, dtype)
            ge = g // isz
            per = ge // num_shards
            start = shard * per
            stop = ge if shard == num_shards - 1 else start + per
            n = stop - start
            tensors.append(
                TensorMeta(
                    name=f"t{k}",
                    shape=(n,),
                    dtype=dt,
                    nbytes=n * isz,
                    global_shape=(ge,),
                    offset=(start,),
                )
            )
            units.append(TransferUnit(index=k, name=f"t{k}", nbytes=n * isz))
        out.append(
            ShardManifest(
                tensors=tuple(tensors),
                units=tuple(units),
                checksums=(0,) * len(units),
            )
        )
    return out


@dataclasses.dataclass
class SimWorker:
    """One shard-owning worker: a GPU with its NIC slice and PCIe lane."""

    worker_id: str
    node: str
    datacenter: str
    up: Link
    down: Link
    pcie: Link
    vpc_up: Link
    vpc_down: Link
    is_spot: bool = False
    alive: bool = True
    total_stall: float = 0.0
    _stall_since: Optional[float] = None
    #: stall decomposition: total_stall split into the canonical
    #: plan_wait / wire / decode / verify / control components
    #: (repro.obs.telemetry.STALL_COMPONENTS). The shard attributes
    #: control-latency yields and flow time observed inside each stalled
    #: window; the residual is plan-wait. Components sum exactly to
    #: total_stall (decode/verify are instantaneous in the fluid model).
    stall_parts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def stall_begin(self, now: float) -> None:
        if self._stall_since is None:
            self._stall_since = now

    def stall_end(self, now: float) -> None:
        if self._stall_since is not None:
            self.total_stall += now - self._stall_since
            self._stall_since = None

    def stall_attribute(
        self, total: float, ctrl: float, wire: float, decode: float = 0.0
    ) -> None:
        """Fold one stalled window's decomposition into ``stall_parts``."""
        parts = self.stall_parts
        parts["control"] = parts.get("control", 0.0) + ctrl
        parts["wire"] = parts.get("wire", 0.0) + wire
        if decode:
            parts["decode"] = parts.get("decode", 0.0) + decode
        parts["plan_wait"] = (
            parts.get("plan_wait", 0.0) + max(0.0, total - ctrl - wire - decode)
        )


class SimCluster:
    """Topology + server + process plumbing."""

    def __init__(
        self,
        *,
        hw: ClusterHW = CLUSTER,
        pipeline_replication: bool = True,
        smart_skipping: bool = True,
        control_latency: Optional[float] = None,
        tcp_compression: Optional[float] = None,
        window: int = DEFAULT_WINDOW,
        chunk_bytes: Optional[float] = DEFAULT_CHUNK_BYTES,
        tcp_streams: int = 1,
        max_sources: int = 4,
        scheduler: str = "least_loaded",
        work_stealing: bool = True,
        swarm: bool = True,
        wan_codec: Optional[str] = None,
        wan_delta: bool = True,
        delta_kept_frac: float = 1.0,
        codec_dtype: str = "float32",
        log: Optional[OpLog] = None,
        telemetry: bool = False,
        retry_policy: Optional[RetryPolicy] = None,
        faults: Optional[FaultPlan] = None,
        quarantine_threshold: int = 3,
        quarantine_probation: float = 30.0,
    ) -> None:
        #: DEPRECATED — ``tcp_compression`` was a hand-set cross-DC
        #: wire-byte scalar whose docstring claimed the int8 ratio while
        #: the default (1.0) compressed nothing. Wire bytes are now
        #: derived from the *negotiated codec*'s actual size formula
        #: (``wan_codec``, default "int8"; see repro.transfer.codec).
        #: Passing the legacy knob preserves the old byte accounting
        #: EXACTLY: the scalar is applied to every WAN TCP flow —
        #: including resharded interval flows, which codec negotiation
        #: keeps raw — and codec-based negotiation is disabled (raw)
        #: unless ``wan_codec`` is also passed explicitly. A fixed-ratio
        #: codec (``wan_codec="fixed:<r>"``) is the non-deprecated way to
        #: model a flat ratio on same-layout WAN pulls.
        self._legacy_tcp_compression: Optional[float] = None
        if tcp_compression is not None:
            warnings.warn(
                "SimCluster(tcp_compression=...) is deprecated; pass "
                'wan_codec="fixed:<ratio>" (or the default "int8") instead',
                DeprecationWarning,
                stacklevel=2,
            )
            if tcp_compression < 1.0:
                self._legacy_tcp_compression = float(tcp_compression)
            if wan_codec is None:
                wan_codec = "raw"
        if wan_codec is None:
            wan_codec = "int8"
        #: wire codec the server negotiates for WAN-crossing slices
        self.wan_codec = wan_codec
        #: delta negotiation knob (see ReferenceServer) and the modeled
        #: version correlation: the fraction of quantization rows that
        #: changed between successive versions. The sim moves no real
        #: bytes, so the delta wire ratio is this knob fed through the
        #: codec's exact size formula (wire_nbytes_at) — 1.0 is the
        #: codec's worst case (every row changed).
        self.wan_delta = bool(wan_delta)
        self.delta_kept_frac = float(delta_kept_frac)
        #: element dtype the fluid simulator assumes when computing a
        #: codec's wire ratio (real manifests carry per-tensor dtypes;
        #: sim manifests are size-only stand-ins for float weights)
        self.codec_dtype = codec_dtype
        #: deprecated alias, kept readable for legacy callers
        self.tcp_compression = 1.0 if tcp_compression is None else tcp_compression
        #: (codec, id(manifest)) -> ratio; entries are evicted by a
        #: weakref finalizer when the manifest is collected, so the cache
        #: neither pins dead replicas' manifests nor outlives id reuse
        self._ratio_cache: Dict[Tuple[str, int], float] = {}
        #: windowed data plane: concurrent unit flows per destination shard
        #: (RDMA/PCIe paths); units above ``chunk_bytes`` are split into
        #: sub-unit byte-range flows. ``window=1`` + ``chunk_bytes=None``
        #: reproduces the pre-scheduler one-flow-at-a-time loop exactly.
        self.window = max(1, window)
        self.chunk_bytes = chunk_bytes if chunk_bytes and chunk_bytes > 0 else None
        #: cross-DC TCP concurrency: streams per shard for WAN fetches.
        #: Kept at 1 by default to preserve the paper-calibrated 2.5 s
        #: seeding transfer (5.4); raising it multi-streams the VPC link.
        self.tcp_streams = max(1, tcp_streams)
        self.env = SimEnv()
        self.net = SimNetwork(self.env)
        #: telemetry recorder on the simulator's virtual clock; stays the
        #: shared disabled singleton unless ``telemetry=True`` so the hot
        #: generator paths record nothing by default. Stall-time
        #: decomposition (stall_parts) is always maintained — it is pure
        #: float accounting on windows the stall counters already track.
        self.recorder = (
            obs.Recorder(clock=lambda: self.env.now) if telemetry else obs.DISABLED
        )
        self.hw = hw
        self.control_latency = (
            hw.control_latency if control_latency is None else control_latency
        )
        self.server = ReferenceServer(
            heartbeat_timeout=hw.heartbeat_timeout,
            pipeline_replication=pipeline_replication,
            smart_skipping=smart_skipping,
            scheduler=scheduler,
            max_sources=max_sources,
            work_stealing=work_stealing,
            # swarm replication: in-progress replicas serve their completed
            # prefix as sources; ``swarm=False`` reproduces the pre-swarm
            # (PR 2) scheduler exactly (benchmarks' parity knob)
            swarm=swarm,
            # chunking disabled means no unit is "giant" to the scheduler:
            # it must not plan around chunk-spreading the data plane will
            # never perform (None would select the server's default hint)
            chunk_hint=(
                self.chunk_bytes if self.chunk_bytes is not None else math.inf
            ),
            # wire codec for WAN-crossing slices (repro.transfer.codec):
            # the sim derives fluid wire bytes from the negotiated
            # codec's size formula per manifest (codec_ratio below)
            wan_codec=wan_codec,
            wan_delta=wan_delta,
            # gray-failure classifier: transient/corrupt evidence
            # strike-counts toward source quarantine instead of eviction
            quarantine_threshold=quarantine_threshold,
            quarantine_probation=quarantine_probation,
            # fault tolerance: replayable op log; crash_and_recover()
            # rebuilds a bit-identical controller from it mid-run
            log=log,
        )
        self.log = log
        self.server.add_watcher(self.env.state_notify)
        self._workers: Dict[Tuple[str, int], SimWorker] = {}
        self._node_seq = itertools.count()
        self.replicas: Dict[str, "SimReplica"] = {}
        #: self-healing knobs (per-read deadline, retry backoff, hedging)
        self.retry_policy = (
            DEFAULT_RETRY_POLICY if retry_policy is None else retry_policy
        )
        #: hedged reads + read-deadline watchdogs are gated off unless the
        #: caller opted in (a fault plan or an explicit policy): they add
        #: wakeup events that would perturb the calibrated healthy-path
        #: benchmark timings
        self._hedging = retry_policy is not None or faults is not None
        self.faults: Optional[SimFaultInjector] = None
        if faults is not None:
            self.install_faults(faults)

    # -- topology -----------------------------------------------------------------

    def _make_worker(
        self, replica: str, shard_idx: int, datacenter: str, node: str, is_spot: bool
    ) -> SimWorker:
        hw = self.hw
        wid = f"{replica}/shard{shard_idx}"
        w = SimWorker(
            worker_id=wid,
            node=node,
            datacenter=datacenter,
            up=self.net.link(f"{node}/{wid}:up", hw.rdma_per_shard),
            down=self.net.link(f"{node}/{wid}:down", hw.rdma_per_shard),
            pcie=self.net.link(f"{node}/{wid}:pcie", hw.pcie),
            vpc_up=self.net.link(f"{node}:vpc_up", hw.vpc_per_node),
            vpc_down=self.net.link(f"{node}:vpc_down", hw.vpc_per_node),
            is_spot=is_spot,
        )
        self._workers[(replica, shard_idx)] = w
        return w

    def worker(self, replica: str, shard_idx: int) -> SimWorker:
        # offload twins live on the origin replica's nodes (CPU memory)
        key = (replica, shard_idx)
        if key not in self._workers and replica.endswith("@offload"):
            origin = replica[: -len("@offload")]
            return self._workers[(origin, shard_idx)]
        return self._workers[key]

    def add_replica(
        self,
        model: str,
        name: str,
        num_shards: int,
        *,
        datacenter: str = "dc0",
        nodes: Optional[Sequence[str]] = None,
        shards_per_node: int = 8,
        is_spot: bool = False,
        retain: Optional[object] = None,
        offload_seeding: bool = False,
        unit_bytes: Sequence[int] = (),
        global_unit_bytes: Optional[Sequence[int]] = None,
    ) -> "SimReplica":
        """``unit_bytes`` sizes one shard's units directly (same-layout
        replicas only); ``global_unit_bytes`` instead sizes the *global*
        model's units and slices them over ``num_shards`` — replicas
        created from the same global sizes with different shard counts
        reshard into each other."""
        rep = SimReplica(
            cluster=self,
            model=model,
            name=name,
            num_shards=num_shards,
            datacenter=datacenter,
            nodes=nodes,
            shards_per_node=shards_per_node,
            is_spot=is_spot,
            retain=retain,
            offload_seeding=offload_seeding,
            unit_bytes=list(unit_bytes),
            global_unit_bytes=(
                None if global_unit_bytes is None else list(global_unit_bytes)
            ),
        )
        self.replicas[name] = rep
        return rep

    # -- wire codecs (fluid byte accounting) ---------------------------------------

    def codec_ratio(self, codec: str, manifest: ShardManifest) -> float:
        """Wire-bytes / payload-bytes multiplier of ``codec`` over one
        shard manifest, from the codec's actual size formula (sim
        manifests are size-only, so elements are assumed ``codec_dtype``).
        Cached per (codec, manifest); a finalizer drops the entry when
        the manifest is garbage collected (id reuse is impossible while
        the entry exists, and churning replicas don't grow the cache)."""
        key = (codec, id(manifest))
        hit = self._ratio_cache.get(key)
        if hit is not None:
            return hit
        ratio = codec_lib.wire_ratio(
            codec_lib.get_codec(codec),
            (u.nbytes for u in manifest.units),
            self.codec_dtype,
            # version correlation for delta codecs; fixed per cluster, so
            # the (codec, manifest) cache key stays sufficient
            delta_kept_frac=self.delta_kept_frac,
        )
        self._ratio_cache[key] = ratio
        weakref.finalize(manifest, self._ratio_cache.pop, key, None)
        return ratio

    # -- failure injection ------------------------------------------------------------

    def crash_and_recover(self) -> "ReferenceServer":
        """Controller failure: kill the server and swap in one recovered
        from the op log (+ compaction snapshot).

        The swap is atomic in virtual time — the crash-sweep harness
        triggers it from the op log's ``on_append`` hook, i.e. at an
        exact op boundary — so sim processes never observe a dead
        controller: their next call lands on the recovered server, which
        is bit-identical to the crashed one up to the committed log.
        (An op in flight at the crash instant finishes against the dead
        server's discarded state; its record is already in the log, so
        the recovered server has applied the same mutation.) The threaded
        client exercises the asynchronous wait-for-failover path instead;
        see ``TensorHubClient.failover``."""
        if self.log is None:
            raise TensorHubError(
                "SimCluster built without an op log cannot recover its "
                "controller; pass log=OpLog(...)"
            )
        self.server.crash()
        new = failover_lib.recover(self.log)
        self.server = new
        new.add_watcher(self.env.state_notify)
        self.env.state_notify()
        return new

    def install_faults(self, plan: FaultPlan) -> "SimFaultInjector":
        """Arm a deterministic gray-fault schedule on this cluster (and
        enable the self-healing machinery — hedged reads, read-deadline
        watchdogs — that a faulted run is meant to exercise)."""
        inj = SimFaultInjector(self, plan)
        inj.install()
        self.faults = inj
        self._hedging = True
        return inj

    def kill_replica(self, name: str) -> None:
        """Spot preemption / node failure: immediate, no grace (5.3)."""
        rep = self.replicas.get(name)
        if rep is not None:
            for s in rep.shards:
                s.worker.alive = False
                s.dead = True
        # flows from/to the victim die; readers notice after the per-read
        # deadline (retry_policy.fail_detect, default = the RDMA timeout)
        self.net.kill_flows(
            lambda f: f.tag.startswith(f"{name}/") or f"->{name}/" in f.tag,
            notice_delay=self.retry_policy.fail_detect,
        )
        # the server learns via missed heartbeats
        self.env.schedule(self.hw.heartbeat_timeout, lambda: self._server_fail(name))
        self._notify_progress_keys(name)

    def _server_fail(self, name: str) -> None:
        for model in list(self.server._models):  # noqa: SLF001 — harness hook
            try:
                self.server.fail_replica(model, name, reason="heartbeat timeout")
            except TensorHubError:
                pass
        self._notify_progress_keys(name)

    def _notify_progress_keys(self, name: str) -> None:
        """Wake every waiter keyed on a dying replica. The shard count is
        derived from the cluster (or, for server-only replicas, from the
        server's registration) rather than a fixed fallback, and a
        predicate sweep catches any remaining keys (control keys, stale
        layouts) so >64-shard replicas cannot miss wakeups."""
        rep = self.replicas.get(name)
        n = rep.num_shards if rep is not None else None
        if n is None:
            for st in self.server._models.values():  # noqa: SLF001 — harness hook
                info = st.replicas.get(name)
                if info is not None:
                    n = info.num_shards
                    break
        names = (name, offload_name(name))
        if n is not None:
            for i in range(n):
                for nm in names:
                    self.env.key_notify(("progress", nm, i))
        self.env.key_notify_where(
            lambda k: isinstance(k, tuple) and len(k) >= 2 and k[1] in names
        )

    # -- metrics -------------------------------------------------------------------------

    def total_stall(self, replicas: Optional[Sequence[str]] = None) -> float:
        names = self.replicas.keys() if replicas is None else replicas
        return sum(
            s.worker.total_stall for n in names for s in self.replicas[n].shards
        )

    def per_worker_stalls(self, replicas: Sequence[str]) -> List[float]:
        return [s.worker.total_stall for n in replicas for s in self.replicas[n].shards]

    def stall_decomposition(
        self, replicas: Optional[Sequence[str]] = None
    ) -> Dict[str, float]:
        """Aggregate stall decomposition over the given replicas (all by
        default): total stall split into the canonical plan_wait / wire /
        decode / verify / control components. Components sum exactly to
        :meth:`total_stall` for the same replica set."""
        names = self.replicas.keys() if replicas is None else replicas
        out = {k: 0.0 for k in obs.STALL_COMPONENTS}
        for n in names:
            for s in self.replicas[n].shards:
                for k, v in s.worker.stall_parts.items():
                    out[k] += v
        return out

    def link_class_bytes(self) -> Dict[str, float]:
        """Wire bytes moved per link class ("up"/"down" RDMA NICs,
        "vpc_up"/"vpc_down" WAN gateways, "pcie" offload lanes),
        aggregated from the fluid network's per-link byte counters. The
        threaded plane exposes matching classes on
        ``LocalTransport.wire_bytes`` ("vpc_up"/"pcie"/"rdma") —
        benchmarks assert sim-vs-threaded WAN parity from these counters
        instead of recomputing bytes by hand."""
        out: Dict[str, float] = {}
        for tag, b in self.net.link_bytes.items():
            cls = tag.rsplit(":", 1)[-1]
            out[cls] = out.get(cls, 0.0) + b
        return out

    def run(self, until: float = math.inf) -> float:
        return self.env.run(until)


class SimShard:
    """Generator-based mirror of ``repro.core.client.ShardHandle``."""

    def __init__(self, replica: "SimReplica", shard_idx: int, worker: SimWorker) -> None:
        self.rep = replica
        self.idx = shard_idx
        self.worker = worker
        self.dead = False
        self._op = itertools.count()
        self._off_op = itertools.count(1_000_000)
        self._seeding: set = set()
        # stall decomposition accounting (pure observation: no events are
        # created or reordered). _ctrl_spent accumulates control-latency
        # yields; the wire tracker maintains the union of this shard's
        # in-flight flow intervals so overlapping windowed flows are not
        # double-counted.
        self._ctrl_spent = 0.0
        self._wire_active = 0
        self._wire_since = 0.0
        self._wire_spent = 0.0
        #: exposed fused-decode time (the backlog tail not hidden under
        #: in-flight interval flows; see _g_pull_resharded)
        self._decode_spent = 0.0

    # plumbing ------------------------------------------------------------------

    @property
    def env(self) -> SimEnv:
        return self.rep.cluster.env

    @property
    def server(self) -> ReferenceServer:
        return self.rep.cluster.server

    @property
    def hw(self) -> ClusterHW:
        return self.rep.cluster.hw

    def _ctrl(self) -> SimEvent:
        # the caller always yields this event immediately, so crediting
        # the latency at creation time keeps the control-time ledger
        # aligned with the stall windows that bracket it
        self._ctrl_spent += self.rep.cluster.control_latency
        return self.env.timeout(self.rep.cluster.control_latency)

    # stall-decomposition ledger (see SimWorker.stall_parts) ----------------

    def _wire_begin(self) -> None:
        if self._wire_active == 0:
            self._wire_since = self.env.now
        self._wire_active += 1

    def _wire_end(self) -> None:
        self._wire_active -= 1
        if self._wire_active == 0:
            self._wire_spent += self.env.now - self._wire_since

    def _wire_snapshot(self) -> float:
        """Wire-time ledger including any currently open interval."""
        if self._wire_active > 0:
            return self._wire_spent + (self.env.now - self._wire_since)
        return self._wire_spent

    def _stall_mark(self) -> Tuple[float, float, float, float]:
        return (
            self.env.now,
            self._ctrl_spent,
            self._wire_snapshot(),
            self._decode_spent,
        )

    def _stall_account(self, mark: Tuple[float, float, float, float]) -> None:
        t0, c0, w0, d0 = mark
        self.worker.stall_attribute(
            self.env.now - t0,
            self._ctrl_spent - c0,
            self._wire_snapshot() - w0,
            self._decode_spent - d0,
        )

    # Table-2 ops (generators) -----------------------------------------------------

    def g_open(self) -> Generator:
        info = WorkerInfo(
            worker_id=self.worker.worker_id,
            node=self.worker.node,
            datacenter=self.worker.datacenter,
            is_spot=self.worker.is_spot,
        )
        yield self._ctrl()
        self.server.open(
            self.rep.model,
            self.rep.name,
            self.rep.num_shards,
            self.idx,
            worker=info,
            retain=self.rep.retain,
        )
        self.server.register(self.rep.model, self.rep.name, self.idx)

    def g_publish(self, version: int) -> Generator:
        yield self._ctrl()
        self.server.publish(
            self.rep.model,
            self.rep.name,
            self.idx,
            version,
            self.rep.manifest_for(self.idx),
            op_id=next(self._op),
        )
        rec = self.rep.cluster.recorder
        if rec.enabled:
            rec.event("publish", track=self.worker.worker_id, version=version)
        self.env.key_notify(("progress", self.rep.name, self.idx))

    def g_unpublish(self) -> Generator:
        yield self._ctrl()
        res = self.server.unpublish(
            self.rep.model, self.rep.name, self.idx, op_id=next(self._op)
        )
        if res.offload_required and res.offload_version is not None:
            yield from self._g_offload_copy(res.offload_version)
        yield from self._g_wait_drained()

    def g_replicate(self, spec, *, stall: bool = True) -> Generator:
        mark = None
        if stall:
            self.worker.stall_begin(self.env.now)
            mark = self._stall_mark()
        op = next(self._op)
        yield self._ctrl()
        assignment = self.server.begin_replicate(
            self.rep.model, self.rep.name, self.idx, spec, op_id=op
        )
        while assignment is None:
            yield self.env.state_wait()
            assignment = self.server.redeem(self.rep.model, self.rep.name, op_id=op)
        rec = self.rep.cluster.recorder
        if rec.enabled:
            rec.event(
                "assignment", track=self.worker.worker_id,
                version=assignment.version, epoch=assignment.epoch,
                sources=[s.source for s in assignment.sources],
                codec=assignment.codec,
            )
        yield from self._g_pull(assignment, dest=self.rep.name)
        if stall:
            self.worker.stall_end(self.env.now)
            self._stall_account(mark)
        return assignment.version

    def g_update(self, spec="latest", *, stall: bool = True) -> Generator:
        """One update() poll; returns True if the weights changed."""
        op = next(self._op)
        yield self._ctrl()
        d = self.server.begin_update(
            self.rep.model,
            self.rep.name,
            self.idx,
            spec,
            op_id=op,
            offload_seeding=self.rep.offload_seeding,
        )
        if d.seed_started and d.seed_version is not None:
            if d.seed_version not in self._seeding:
                self._seeding.add(d.seed_version)
                self.env.process(self._g_seed_pull(d.seed_version))
        if not d.updated:
            return False
        mark = None
        if stall:
            self.worker.stall_begin(self.env.now)
            mark = self._stall_mark()
        if d.offload_required and d.offload_version is not None:
            yield from self._g_offload_copy(d.offload_version)
        yield from self._g_wait_drained()
        assert d.assignment is not None
        rec = self.rep.cluster.recorder
        if rec.enabled:
            rec.event(
                "assignment", track=self.worker.worker_id,
                version=d.assignment.version, epoch=d.assignment.epoch,
                sources=[s.source for s in d.assignment.sources],
                codec=d.assignment.codec,
            )
        yield from self._g_pull(d.assignment, dest=self.rep.name)
        if stall:
            self.worker.stall_end(self.env.now)
            self._stall_account(mark)
        return True

    # internals ---------------------------------------------------------------------

    def _g_wait_drained(self) -> Generator:
        while not self.server.finish_unpublish(self.rep.model, self.rep.name):
            yield self.env.state_wait()

    def _g_timed_flow(self, ev, name, source, nbytes, codec, transport) -> Generator:
        """Yield a flow event under the wire ledger (and a span when the
        cluster recorder is enabled). Pure observation: the event passes
        through unchanged, so scheduling and byte accounting are
        bit-identical to yielding the flow directly."""
        rec = self.rep.cluster.recorder
        sp = None
        if rec.enabled:
            sp = rec.span(
                name, track=self.worker.worker_id, source=source,
                bytes=nbytes, codec=codec, transport=transport,
            )
        self._wire_begin()
        try:
            yield ev
        finally:
            self._wire_end()
            if sp is not None:
                sp.end()

    def _g_offload_copy(self, version: int) -> Generator:
        """Retention offload: GPU -> CPU over PCIe, then publish_offload."""
        nbytes = self.rep.manifest_for(self.idx).total_bytes
        yield from self._g_timed_flow(
            self.rep.cluster.net.flow(
                nbytes, [self.worker.pcie], tag=f"{self.rep.name}/s{self.idx}:offload"
            ),
            "offload_copy", self.rep.name, nbytes, "raw", "pcie",
        )
        yield self._ctrl()
        self.server.publish_offload(
            self.rep.model,
            self.rep.name,
            self.idx,
            version,
            self.rep.manifest_for(self.idx),
            op_id=next(self._op),
        )
        self.env.key_notify(("progress", offload_name(self.rep.name), self.idx))

    def _flow_for_bytes(
        self,
        src_replica: str,
        src_shard: int,
        nbytes: float,
        transport: str,
        dest_name: str,
        codec: str = "raw",
    ) -> SimEvent:
        cluster = self.rep.cluster
        src_w = cluster.worker(src_replica, src_shard)
        dst_w = self.worker
        hw = self.hw
        if src_w.node == dst_w.node:
            links = [dst_w.pcie]  # local CPU<->GPU consumption (seed twins)
            cap = hw.pcie
        elif transport == "tcp":
            links = [src_w.vpc_up, dst_w.vpc_down]
            # WAN TCP streams are stream-limited before they are NIC-limited
            cap = min(hw.tensorhub_tcp_eff * hw.vpc_per_node, hw.tcp_stream_per_shard)
        else:
            links = [src_w.up, dst_w.down]
            cap = hw.tensorhub_rdma_eff * hw.rdma_per_shard
        legacy = cluster._legacy_tcp_compression
        if legacy is not None and transport == "tcp":
            # deprecated tcp_compression scalar: the pre-codec behavior
            # verbatim — every WAN TCP flow scaled, resharded interval
            # flows included (codec negotiation keeps those raw)
            nbytes = nbytes * legacy
        elif codec != "raw":
            # the negotiated wire codec moves fewer (or framed) bytes; the
            # multiplier comes from the codec's size formula over this
            # shard's manifest, not a hand-set scalar
            nbytes = nbytes * cluster.codec_ratio(
                codec, self.rep.manifest_for(self.idx)
            )
        tag = f"{src_replica}/s{src_shard}->{dest_name}/s{self.idx}"
        ev = cluster.net.flow(
            nbytes, links, rate_cap=cap, latency=hw.unit_latency, tag=tag
        )
        if cluster.faults is not None and cluster.faults.flaky_hit(
            src_replica, self.env.now
        ):
            # injected flake: the flow starts, then dies almost at once
            # with a *transient* kill (the endpoint is fine — the reader
            # backs off and retries). Scheduled past the flow's start
            # latency so kill_flows sees it attached.
            self.env.schedule(
                hw.unit_latency * 2,
                lambda: cluster.net.kill_flows(
                    lambda f: f.event is ev, transient=True
                ),
            )
        return ev

    def _g_pull(self, assignment: Assignment, *, dest: str) -> Generator:
        """The pipeline-replication read loop (4.3.3) in virtual time.

        Dispatches per assignment: same-layout sources stream whole units
        (multi-source plans partition the unit list across replicas and
        pull them through a windowed, chunked flow pool); a source with a
        different shard count runs the resharding plan (striped interval
        flows from *all* source shards). Progress counts completed
        destination units either way, so a re-route or re-partition
        mid-transfer may switch modes and resume (4.5).
        """
        version = assignment.version
        completed: set = set()  # out-of-order completions, kept across re-plans
        rejects: Dict[int, int] = {}  # unit -> checksum rejects, across re-plans
        while True:
            try:
                if assignment.resharded:
                    yield from self._g_pull_resharded(assignment, dest)
                else:
                    yield from self._g_pull_units(
                        assignment, dest, completed, rejects
                    )
                break
            except _SimReplan:
                assignment = yield from self._g_refetch(dest)
            except _SimSourceLost as e:
                assignment = yield from self._g_reroute(dest, e.source, e.evidence)
        yield self._ctrl()
        self.server.complete_replicate(
            self.rep.model,
            dest,
            self.idx,
            version,
            op_id=next(self._off_op) if dest != self.rep.name else next(self._op),
        )

    def _g_await_source_unit(
        self, source: str, version: int, src_shard: int, needed: int
    ) -> Generator:
        """Wait until the source shard's progress counter exceeds
        ``needed``. Purely keyed wakeups backed by the event loop's long
        safety tick (SimEnv.safety_tick) instead of the old 0.5 s polling
        timeout (measurable wakeup overhead at large fan-out — and the
        stale poll timers inflated ``env.now`` after runs finished)."""
        env = self.env
        while True:
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            try:
                avail = self.server.shard_progress(
                    self.rep.model, source, version, src_shard
                )
            except (StaleHandleError, TensorHubError):
                raise _SimSourceLost(source)
            if avail > needed:
                return avail
            yield env.key_wait(("progress", source, src_shard))

    # -- same-layout unit pulls: windowed, chunked, multi-source ----------------

    def _plane_knobs(self, slices: List[SourceSlice]) -> Tuple[int, Optional[float]]:
        """Window depth and chunk threshold for this pull. WAN TCP pulls
        follow ``tcp_streams`` (default 1: preserves the paper-calibrated
        single-stream seeding transfer); RDMA/PCIe pulls use the cluster's
        window/chunk knobs."""
        cl = self.rep.cluster
        if any(sl.transport == "tcp" for sl in slices):
            window = cl.tcp_streams
            chunk = cl.chunk_bytes if cl.tcp_streams > 1 else None
        else:
            window = cl.window
            chunk = cl.chunk_bytes
        return window, chunk

    def _g_pull_units(
        self,
        assignment: Assignment,
        dest: str,
        completed: Optional[set] = None,
        rejects: Optional[Dict[int, int]] = None,
    ) -> Generator:
        version = assignment.version
        units = self.rep.manifest_for(self.idx).units
        if completed is None:
            completed = set()
        if rejects is None:
            rejects = {}
        while True:
            done = self.server.shard_progress(self.rep.model, dest, version, self.idx)
            if done >= len(units):
                return
            # units completed out of order survive re-plans (their bytes
            # are final); only the uncompleted ones are re-fetched
            completed -= set(range(done))
            slices = assignment.slices(len(units))
            window, chunk = self._plane_knobs(slices)
            if window <= 1 and chunk is None and len(slices) == 1:
                yield from self._g_pull_units_seq(assignment, dest, rejects)
                return
            yield from self._g_pull_units_windowed(
                assignment, dest, slices, done, window, chunk, completed, rejects
            )

    def _g_pull_units_seq(
        self,
        assignment: Assignment,
        dest: str,
        rejects: Optional[Dict[int, int]] = None,
    ) -> Generator:
        """The pre-scheduler data plane: one whole-unit flow at a time from
        a single source. Kept verbatim as the window=1/chunking-off
        reference path (benchmarks compare against it bit-for-bit; the
        retry/corrupt branches are reachable only with faults armed)."""
        env = self.env
        version = assignment.version
        manifest = self.rep.manifest_for(self.idx)
        units = manifest.units
        source = assignment.source
        transport = assignment.transport
        codec = assignment.codec
        cl = self.rep.cluster
        policy = cl.retry_policy
        if rejects is None:
            rejects = {}
        done = self.server.shard_progress(self.rep.model, dest, version, self.idx)
        while done < len(units):
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            avail = yield from self._g_await_source_unit(
                source, version, self.idx, done
            )
            for i in range(done, avail):
                attempt = 0
                while True:
                    try:
                        yield from self._g_timed_flow(
                            self._flow_for_bytes(
                                source, self.idx, units[i].nbytes, transport,
                                dest, codec=codec,
                            ),
                            "flow", source, units[i].nbytes, codec, transport,
                        )
                        break
                    except FlowKilled as e:
                        if self.dead:
                            raise PreemptedError(self.worker.worker_id)
                        if not e.transient or attempt >= policy.retry_limit:
                            raise _SimSourceLost(
                                source,
                                evidence="transient" if e.transient else "fatal",
                            )
                        attempt += 1
                        yield env.timeout(policy.backoff(attempt))
                if cl.faults is not None and cl.faults.corrupt_hit(
                    source, env.now
                ):
                    # injected corruption: the destination-side checksum
                    # rejects the unit; report and re-plan rather than
                    # abort, bounded per unit (see the threaded plane)
                    rejects[i] = rejects.get(i, 0) + 1
                    if rejects[i] > policy.retry_limit:
                        raise TensorHubError(
                            f"unit {units[i].name}: {rejects[i]} checksum "
                            "rejects across re-plans; data is corrupt at "
                            "every source"
                        )
                    raise _SimSourceLost(source, evidence="corrupt")
                done += 1
                self.server.update_progress(
                    self.rep.model, dest, self.idx, version, done
                )
                rec = self.rep.cluster.recorder
                if rec.enabled:
                    rec.event("prefix_advance", track=self.worker.worker_id, done=done)
                env.key_notify(("progress", dest, self.idx))

    def _build_tasks(
        self,
        slices: List[SourceSlice],
        units: Sequence[TransferUnit],
        done: int,
        chunk: Optional[float],
        completed: set,
    ) -> List[_Task]:
        """Expand the plan's unit ranges into an ordered task list. Units
        above the chunk threshold become byte-range tasks; with several
        sources the chunks of one unit are owner-hinted round-robin across
        *all* of them — same-layout replicas hold identical bytes, so a
        single giant tensor can aggregate every source uplink instead of
        binding to its range owner. Units in ``completed`` (finished out
        of order before a re-plan) are skipped."""
        owners: Dict[int, int] = {}
        for k, sl in enumerate(slices):
            for ui in range(max(sl.start_unit, done), min(sl.stop_unit, len(units))):
                owners.setdefault(ui, k)
        tasks: List[_Task] = []
        rr = 0
        for ui in range(done, len(units)):
            if ui in completed:
                continue
            k = owners.get(ui, 0)
            nbytes = units[ui].nbytes
            if chunk is not None and nbytes > chunk:
                n_parts = int(math.ceil(nbytes / chunk))
                per = nbytes / n_parts  # fluid bytes: equal fractional chunks
                for j in range(n_parts):
                    tgt = (rr + j) % len(slices) if len(slices) > 1 else k
                    tasks.append(_Task(ui, j * per, per, tgt))
                rr += n_parts
            else:
                tasks.append(_Task(ui, 0, nbytes, k))
        return tasks

    def _g_pull_units_windowed(
        self,
        assignment: Assignment,
        dest: str,
        slices: List[SourceSlice],
        done: int,
        window: int,
        chunk: Optional[float],
        completed: set,
        rejects: Optional[Dict[int, int]] = None,
    ) -> Generator:
        """Windowed multi-source pull: one worker process per source slice,
        a shared slot pool capping in-flight flows at ``window`` per shard,
        and in-order prefix advancement of the progress counter (units may
        *complete* out of order across sources; the counter — which gates
        downstream pipeline chains and mid-transfer re-routing — only ever
        advances over a contiguous prefix).

        Execution is availability-aware: the server's unit ranges are load
        hints, not bindings. A worker claims tasks from its own range
        first, then steals unclaimed tasks from the global tail — but only
        tasks its source can already serve (progress gating). Pipeline
        chaining off partial replicas and bandwidth aggregation across
        published ones fall out of the same loop."""
        env = self.env
        version = assignment.version
        units = self.rep.manifest_for(self.idx).units
        tasks = self._build_tasks(slices, units, done, chunk, completed)
        if not tasks:
            return
        remaining: Dict[int, int] = {}
        for t in tasks:
            remaining[t.unit] = remaining.get(t.unit, 0) + 1
        state = {
            "done": done,
            "completed": completed,  # shared with the caller: survives re-plans
            "remaining": remaining,
            "tasks": tasks,
            "claimed": [False] * len(tasks),
            "unclaimed": len(tasks),
            "scan": 0,  # first possibly-unclaimed task index
            "stop": None,  # None | "replan" | BaseException
            "epoch": assignment.epoch,
            # self-healing state --------------------------------------
            "rejects": rejects if rejects is not None else {},
            "taskdone": [False] * len(tasks),  # completion claims
            "ntaskdone": 0,
            "inflight": {},  # task idx -> (start, source, worker idx)
            "durations": [],  # completed flow durations (hedge baseline)
            "hedged": set(),  # task idxs already duplicated once
            "finished": False,  # parent's signal to the watchdog
        }
        ctl = ("ctl", dest, self.idx)
        slots = _SimSlots(env, window)
        children = [
            env.process(
                self._g_source_worker(k, sl, state, slots, dest, version, ctl)
            )
            for k, sl in enumerate(slices)
        ]
        if self.rep.cluster._hedging:
            # faulted/healing runs only: per-read deadline watchdog (adds
            # timer events, so gated off the calibrated healthy paths)
            env.process(self._g_span_watchdog(state, dest, version, ctl))
        done_ev = SimEvent(env)
        pending = len(children)

        def on_child(ev: SimEvent) -> None:
            nonlocal pending
            if ev.error is not None and not isinstance(state["stop"], BaseException):
                state["stop"] = ev.error
                env.key_notify(ctl)
            pending -= 1
            if pending == 0:
                done_ev.succeed()

        for c in children:
            c.add_callback(on_child)
        yield done_ev
        state["finished"] = True
        if self.dead:
            raise PreemptedError(self.worker.worker_id)
        stop = state["stop"]
        if isinstance(stop, BaseException):
            raise stop
        if stop == "replan":
            raise _SimReplan()

    def _g_source_worker(
        self,
        k: int,
        sl: SourceSlice,
        state: dict,
        slots: _SimSlots,
        dest: str,
        version: int,
        ctl: tuple,
    ) -> Generator:
        env = self.env
        cl = self.rep.cluster
        policy = cl.retry_policy
        hedging = cl._hedging
        rec = cl.recorder
        tasks: List[_Task] = state["tasks"]
        claimed: List[bool] = state["claimed"]
        taskdone: List[bool] = state["taskdone"]
        while True:
            if state["stop"] is not None:
                return
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            # pick up server-side re-partitions (work stealing, re-routes)
            try:
                ep = self.server.assignment_epoch(self.rep.model, dest, version)
            except (StaleHandleError, TensorHubError):
                return  # dest state gone; the parent unwinds
            if ep != state["epoch"]:
                if state["stop"] is None:
                    state["stop"] = "replan"
                    env.key_notify(ctl)
                return
            if state["ntaskdone"] == len(tasks):
                return
            try:
                avail = self.server.shard_progress(
                    self.rep.model, sl.source, version, self.idx
                )
            except (StaleHandleError, TensorHubError):
                raise _SimSourceLost(sl.source)
            # Global in-order claiming: take the LOWEST-indexed unclaimed
            # task this source can serve. Keeping the in-flight window on
            # the head of the unit list makes the progress *prefix* (which
            # gates downstream pipeline chains) advance at full aggregate
            # rate; claiming ranges out of order would starve relays to
            # 1/window of the bandwidth. Faster/idler sources win more
            # claims, so load balances itself around the server's ranges.
            # The `tasks[i].unit < avail` predicate is ALSO the simulator's
            # never-read-past-source-prefix guard (swarm replication): a
            # claim is legal only for units the source's completed prefix
            # covers, and progress is monotone, so a claimed unit can never
            # outrun its source — in-progress replicas serve exactly their
            # prefix (SourceSlice.ceiling is the plan-time snapshot of it).
            while state["scan"] < len(tasks) and claimed[state["scan"]]:
                state["scan"] += 1
            pick = None
            hedged = False
            for i in range(state["scan"], len(tasks)):
                if not claimed[i] and tasks[i].unit < avail:
                    pick = i
                    break
            if pick is None and hedging:
                # idle with no unclaimed work: duplicate the slowest
                # foreign in-flight flow instead (bounds single-source
                # straggling at roughly the healthy source's speed; the
                # first copy to finish claims the task)
                pick = self._sim_hedge_pick(state, sl, avail, policy)
                if pick is not None:
                    hedged = True
                    if rec.enabled:
                        rec.counter_add(obs.CTR_HEDGES, 1)
                        rec.event(
                            "hedge", track=self.worker.worker_id,
                            source=sl.source, unit=tasks[pick].unit,
                        )
            if pick is None:
                # nothing this source can serve yet: wait for its progress
                # (plus, when hedging, a timer for the next straggler
                # becoming hedge-eligible — a stuck flow notifies nothing)
                waits = [
                    env.key_wait(("progress", sl.source, self.idx)),
                    env.key_wait(ctl),
                ]
                if hedging:
                    delay = self._sim_hedge_delay(state, sl, policy)
                    if delay is not None:
                        waits.append(env.timeout(delay))
                yield env.any_of(*waits)
                continue
            if not hedged:
                claimed[pick] = True
                state["unclaimed"] -= 1
                if state["unclaimed"] == 0:
                    env.key_notify(ctl)  # wake gated siblings so they can exit
            t = tasks[pick]
            yield slots.acquire()
            if state["stop"] is not None or taskdone[pick]:
                slots.release()
                if state["stop"] is not None:
                    return
                continue  # hedge twin finished while we queued for a slot
            started = env.now
            state["inflight"][pick] = (started, sl.source, k)
            attempt = 0
            failed = None
            delivered = False
            try:
                while True:
                    try:
                        yield from self._g_timed_flow(
                            self._flow_for_bytes(
                                sl.source, self.idx, t.nbytes, sl.transport,
                                dest, codec=sl.codec,
                            ),
                            "flow", sl.source, t.nbytes, sl.codec, sl.transport,
                        )
                        delivered = True
                        break
                    except FlowKilled as e:
                        if self.dead:
                            raise PreemptedError(self.worker.worker_id)
                        if e.transient and (
                            state["stop"] is not None or taskdone[pick]
                        ):
                            break  # span drained / hedge twin won: abandon
                        if not e.transient or attempt >= policy.retry_limit:
                            failed = _SimSourceLost(
                                sl.source,
                                evidence="transient" if e.transient else "fatal",
                            )
                            break
                        attempt += 1
                        if rec.enabled:
                            rec.counter_add(obs.CTR_RETRIES, 1)
                            rec.event(
                                "retry", track=self.worker.worker_id,
                                source=sl.source, unit=t.unit, attempt=attempt,
                            )
                        yield env.timeout(policy.backoff(attempt))
                        if state["stop"] is not None or taskdone[pick]:
                            break  # abandoned mid-retry; drop the attempt
            finally:
                cur = state["inflight"].get(pick)
                if cur is not None and cur[2] == k:
                    del state["inflight"][pick]
                slots.release()
            if failed is not None:
                raise failed
            if not delivered:
                # flow abandoned mid-kill/retry: nothing arrived
                if state["stop"] is not None:
                    return
                continue  # hedge twin won while we were backing off
            if taskdone[pick]:
                continue  # hedge twin won the race; identical bytes, drop
            if cl.faults is not None and cl.faults.corrupt_hit(sl.source, env.now):
                # injected corruption: the destination-side checksum
                # rejects the unit; report + re-plan, bounded per unit
                u = t.unit
                state["rejects"][u] = state["rejects"].get(u, 0) + 1
                if state["rejects"][u] > policy.retry_limit:
                    raise TensorHubError(
                        f"unit {u}: {state['rejects'][u]} checksum rejects "
                        "across re-plans; data is corrupt at every source"
                    )
                if rec.enabled:
                    rec.counter_add(obs.CTR_CORRUPT_REJECTS, 1)
                    rec.event(
                        "corrupt_reject", track=self.worker.worker_id,
                        source=sl.source, unit=u,
                    )
                raise _SimSourceLost(sl.source, evidence="corrupt")
            taskdone[pick] = True
            state["ntaskdone"] += 1
            state["durations"].append(env.now - started)
            if state["ntaskdone"] == len(tasks):
                env.key_notify(ctl)  # wake hedging siblings so they can exit
                if hedging and state["inflight"]:
                    # a hedge twin finished last: losers still crawling on
                    # a straggler would pin the span (the parent joins all
                    # workers) — kill their flows with a transient notice
                    cl.net.kill_flows(
                        lambda f: f.tag.endswith(f"->{dest}/s{self.idx}"),
                        transient=True,
                    )
            rem = state["remaining"][t.unit] - 1
            state["remaining"][t.unit] = rem
            if rem == 0:
                state["completed"].add(t.unit)
                advanced = False
                while state["done"] in state["completed"]:
                    state["done"] += 1
                    advanced = True
                if advanced:
                    self.server.update_progress(
                        self.rep.model, dest, self.idx, version, state["done"]
                    )
                    env.key_notify(("progress", dest, self.idx))

    def _sim_hedge_pick(
        self, state: dict, sl: SourceSlice, avail: int, policy: RetryPolicy
    ) -> Optional[int]:
        """Oldest in-flight task worth duplicating onto this idle source:
        running longer than ``hedge_threshold`` x the median completed
        flow, owned by a different source, not already hedged, and within
        this source's served prefix."""
        durs = state["durations"]
        if len(durs) < policy.hedge_min_samples:
            return None
        med = sorted(durs)[len(durs) // 2]
        threshold = policy.hedge_threshold * max(med, 1e-9)
        now = self.env.now
        tasks: List[_Task] = state["tasks"]
        pick = None
        oldest = None
        for ti, (started, src, _k) in state["inflight"].items():
            if src == sl.source or ti in state["hedged"]:
                continue
            if state["taskdone"][ti] or tasks[ti].unit >= avail:
                continue
            age = now - started
            if age >= threshold and (oldest is None or age > oldest):
                oldest = age
                pick = ti
        if pick is not None:
            state["hedged"].add(pick)
        return pick

    def _sim_hedge_delay(
        self, state: dict, sl: SourceSlice, policy: RetryPolicy
    ) -> Optional[float]:
        """Virtual-time delay until the next foreign in-flight flow could
        become hedge-eligible (None when nothing qualifies — then the
        keyed progress/ctl wakeups suffice)."""
        durs = state["durations"]
        if len(durs) < policy.hedge_min_samples:
            return None
        med = sorted(durs)[len(durs) // 2]
        threshold = policy.hedge_threshold * max(med, 1e-9)
        now = self.env.now
        best = None
        for ti, (started, src, _k) in state["inflight"].items():
            if src == sl.source or ti in state["hedged"] or state["taskdone"][ti]:
                continue
            wait = (started + threshold) - now
            if best is None or wait < best:
                best = wait
        if best is None:
            return None
        return max(best, self.hw.unit_latency)

    def _g_span_watchdog(
        self, state: dict, dest: str, version: int, ctl: tuple
    ) -> Generator:
        """Per-read deadline enforcement for one windowed span (faulted /
        healing runs only). A flow in flight past ``fail_detect`` is
        *transient* evidence against its source — reported (rate-limited
        per source) so the server strike-counts toward quarantine. When
        the resulting re-plan bumps the epoch, the watchdog drains the
        span and transiently kills its inbound flows so workers blocked
        on a hung (zero-bandwidth) flow wake up and exit."""
        env = self.env
        cl = self.rep.cluster
        policy = cl.retry_policy
        rec = cl.recorder
        last_report: Dict[str, float] = {}
        tick = max(policy.fail_detect / 2.0, cl.hw.unit_latency)
        while True:
            yield env.timeout(tick)
            if state["finished"] or state["stop"] is not None or self.dead:
                return
            now = env.now
            overdue: List[str] = []
            for ti, (started, src, _k) in list(state["inflight"].items()):
                if state["taskdone"][ti]:
                    continue
                if now - started >= policy.fail_detect:
                    prev = last_report.get(src)
                    if prev is None or now - prev >= policy.fail_detect:
                        last_report[src] = now
                        overdue.append(src)
            for src in overdue:
                if rec.enabled:
                    rec.counter_add(obs.CTR_DEADLINE_REPORTS, 1)
                    rec.event(
                        "read_deadline", track=self.worker.worker_id, source=src
                    )
                try:
                    self.server.report_transfer_failure(
                        self.rep.model, dest, src, "transient", now
                    )
                except (StaleHandleError, TensorHubError):
                    return  # dest state gone; workers unwind on their own
            try:
                ep = self.server.assignment_epoch(self.rep.model, dest, version)
            except (StaleHandleError, TensorHubError):
                return
            if ep != state["epoch"]:
                if state["stop"] is None:
                    state["stop"] = "replan"
                    env.key_notify(ctl)
                cl.net.kill_flows(
                    lambda f: f.tag.endswith(f"->{dest}/s{self.idx}"),
                    transient=True,
                )
                return

    def _g_refetch(self, dest: str) -> Generator:
        """Re-fetch the (re-partitioned) assignment after a plan epoch
        bump; no failure to report."""
        yield self._ctrl()
        while True:
            if self.dead:
                raise PreemptedError(self.worker.worker_id)
            try:
                new = self.server.get_assignment(self.rep.model, dest)
            except StaleHandleError:
                if self.dead:
                    raise PreemptedError(self.worker.worker_id)
                raise
            if new is not None:
                return new
            yield self.env.state_wait()

    def _g_pull_resharded(self, assignment: Assignment, dest: str) -> Generator:
        """Striped cross-layout pull in virtual time: real planner, fluid
        bytes. Each interval flows over the *owning* source shard's NIC,
        so bandwidth aggregates across all source shards exactly as the
        byte accounting says it should.

        The negotiated wire codec rides the plan exactly as in the
        threaded plane: ``reshard_wire_codec`` collapses delta to its
        base, the planner widens reads to the codec's row grid
        (``iv.read_nbytes`` is what flows), and a lossy codec models the
        fused client-side decode as a backlog drained at roughly a third
        of HBM bandwidth — hidden under the next unit's flows, with only
        the tail exposed (ledgered as ``decode`` stall)."""
        from repro.resharding import layout_from_manifests, plan_shard

        codec = codec_lib.reshard_wire_codec(assignment.codec)
        fused = codec != "raw"
        env = self.env
        version = assignment.version
        src_n = assignment.source_shards
        local_manifest = self.rep.manifest_for(self.idx)
        self.server.put_manifest(
            self.rep.model, dest, self.idx, version, local_manifest
        )
        source = assignment.source
        src_manifests = {}
        for s in range(src_n):
            while True:
                m = self.server.replica_manifest(self.rep.model, version, source, s)
                if m is not None:
                    break
                yield env.state_wait()
                if self.dead:
                    raise PreemptedError(self.worker.worker_id)
            src_manifests[s] = m
        src_layout = layout_from_manifests(src_manifests, src_n)
        dst_layout = layout_from_manifests(
            {self.idx: local_manifest}, self.rep.num_shards
        )
        plan = plan_shard(
            src_layout,
            dst_layout,
            self.idx,
            num_dest_units=local_manifest.num_units,
            codec=codec,
        )
        by_unit = plan.intervals_by_unit()
        transport = assignment.transport
        done = self.server.shard_progress(self.rep.model, dest, version, self.idx)
        decode_bw = TPU.hbm_bw / 3.0  # fused dequant+gather drain rate
        backlog = 0.0  # decode seconds not yet hidden under flows
        for unit in local_manifest.units[done:]:
            t_unit = env.now
            for iv in by_unit.get(unit.index, []):
                yield from self._g_await_source_unit(
                    source, version, iv.source_shard, iv.source_unit
                )
                try:
                    yield from self._g_timed_flow(
                        self._flow_for_bytes(
                            source, iv.source_shard, iv.read_nbytes, transport,
                            dest, codec=codec,
                        ),
                        "interval_flow", source, iv.read_nbytes, codec,
                        transport,
                    )
                except FlowKilled:
                    if self.dead:
                        raise PreemptedError(self.worker.worker_id)
                    raise _SimSourceLost(source)
            if fused:
                # one-unit lookahead: the previous unit's decode drained
                # while this unit's intervals were in flight
                backlog = max(0.0, backlog - (env.now - t_unit))
                backlog += unit.nbytes / decode_bw
            done += 1
            self.server.update_progress(self.rep.model, dest, self.idx, version, done)
            env.key_notify(("progress", dest, self.idx))
        if backlog > 0.0:
            # the last unit's decode has no flows left to hide under
            t0 = env.now
            yield env.timeout(backlog)
            self._decode_spent += env.now - t0

    def _g_reroute(
        self, dest: str, dead_source: str, evidence: str = "fatal"
    ) -> Generator:
        if self.dead:
            raise PreemptedError(self.worker.worker_id)
        yield self._ctrl()
        self.server.report_transfer_failure(
            self.rep.model, dest, dead_source, evidence, self.env.now
        )
        while True:
            new = self.server.get_assignment(self.rep.model, dest)
            if new is not None:
                return new
            yield self.env.state_wait()
            if self.dead:
                raise PreemptedError(self.worker.worker_id)

    def _g_seed_pull(self, version: int) -> Generator:
        """Background cross-DC fetch into CPU memory (offload seeding,
        4.3.4) — does NOT count as GPU stall."""
        twin = offload_name(self.rep.name)
        while True:
            assignment = self.server.get_assignment(self.rep.model, twin)
            if assignment is not None:
                break
            yield self.env.state_wait()
        yield from self._g_pull(assignment, dest=twin)


class SimReplica:
    """A model-parallel group of SimShards."""

    def __init__(
        self,
        *,
        cluster: SimCluster,
        model: str,
        name: str,
        num_shards: int,
        datacenter: str,
        nodes: Optional[Sequence[str]],
        shards_per_node: int,
        is_spot: bool,
        retain: Optional[object],
        offload_seeding: bool,
        unit_bytes: List[int],
        global_unit_bytes: Optional[List[int]] = None,
    ) -> None:
        self.cluster = cluster
        self.model = model
        self.name = name
        self.num_shards = num_shards
        self.datacenter = datacenter
        self.is_spot = is_spot
        self.retain = retain
        self.offload_seeding = offload_seeding
        self.unit_bytes = unit_bytes
        self.global_unit_bytes = global_unit_bytes
        # manifests declare the cluster's codec dtype so the server's
        # codec negotiation sees the quantizable payload the fluid byte
        # accounting already assumes
        if global_unit_bytes is not None:
            self.manifests = make_layout_manifests(
                global_unit_bytes, num_shards, dtype=cluster.codec_dtype
            )
        else:
            self.manifests = [
                make_manifest(unit_bytes, dtype=cluster.codec_dtype)
            ] * num_shards
        self.manifest = self.manifests[0]
        self.shard_bytes = self.manifests[0].total_bytes
        self.shards: List[SimShard] = []
        for i in range(num_shards):
            node = (
                nodes[i // shards_per_node]
                if nodes is not None
                else f"{datacenter}/{name}-n{i // shards_per_node}"
            )
            w = cluster._make_worker(name, i, datacenter, node, is_spot)
            self.shards.append(SimShard(self, i, w))

    def manifest_for(self, shard_idx: int) -> ShardManifest:
        return self.manifests[shard_idx]

    # -- group-level helpers: run an op on every shard, fire when all done ------------

    def _all(self, gens: List[Generator]) -> SimEvent:
        """Start one process per shard; the returned event fires (with the
        list of per-shard results) when all of them finished. A failing
        shard fails the group event."""
        env = self.cluster.env
        done = SimEvent(env)
        remaining = len(gens)
        results: List[object] = [None] * len(gens)

        def on_finish(i: int) -> Callable[[SimEvent], None]:
            def cb(ev: SimEvent) -> None:
                nonlocal remaining
                if ev.error is not None:
                    done.fail(ev.error)
                    return
                results[i] = ev.value
                remaining -= 1
                if remaining == 0:
                    done.succeed(results)

            return cb

        for i, g in enumerate(gens):
            env.process(g).add_callback(on_finish(i))
        return done

    def open(self) -> SimEvent:
        return self._all([s.g_open() for s in self.shards])

    def publish(self, version: int) -> SimEvent:
        return self._all([s.g_publish(version) for s in self.shards])

    def unpublish(self) -> SimEvent:
        return self._all([s.g_unpublish() for s in self.shards])

    def replicate(self, spec="latest", *, stall: bool = True) -> SimEvent:
        return self._all([s.g_replicate(spec, stall=stall) for s in self.shards])

    def update(self, spec="latest", *, stall: bool = True) -> SimEvent:
        return self._all([s.g_update(spec, stall=stall) for s in self.shards])
