"""Wire codecs: pluggable per-link payload encodings for the data plane.

The paper's cross-DC workload (5.4) wins by moving fewer bytes over the
WAN. This module makes that real: a :class:`WireCodec` transforms a
transfer-unit payload into *wire bytes* at the source and back into
weight bytes at the destination. The reference server negotiates the
codec **per link class** when it builds an :class:`~repro.core.meta.Assignment`:
WAN-crossing slices default to ``int8`` (symmetric per-row quantization,
backed by the Pallas kernel package ``repro.kernels.quant``, with a
pure-NumPy implementation when JAX is absent), intra-DC slices stay
``raw``. The negotiated name travels on ``SourceSlice.codec`` /
``Assignment.codec`` and is honored by both data planes
(``repro.transfer.engine`` for real bytes, ``repro.transfer.simcluster``
for fluid bytes).

Integrity contract (4.6)
------------------------
End-to-end checksums are verified over the **decoded** bytes:

* ``raw`` — the manifest's publish-time per-unit checksum, exactly as
  before (bit-for-bit the pre-codec wire).
* lossy codecs (``int8``) — the publish-time checksum cannot match the
  de-quantized bytes, so the source checksums ``decode(encode(payload))``
  at read time and the destination re-verifies its decoded copy — the
  same transit protection contract as ``LocalTransport.read_unit_range``.
  Additionally the wire header carries dtype / row length / payload size
  and the decoder validates all of them plus scale finiteness (the
  wire-level scale/shape integrity check), so a torn or misframed wire
  buffer fails loudly instead of decoding garbage.

Chunk alignment
---------------
Sub-unit chunking composes with quantization because rows are a pure
function of element *position*: a chunk whose byte offset is a multiple
of :meth:`WireCodec.row_bytes` encodes exactly the same (scale, q) rows
as the corresponding slice of the whole-unit encoding, so chunked giant
units reassemble bit-identically to a single-flow transfer. The client's
task builder aligns chunk boundaries accordingly; the transport rejects
misaligned non-raw range reads.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Iterable, Mapping, Optional, Tuple

import numpy as np

from repro.core.errors import TensorHubError
from repro.core.meta import TensorMeta, TransferUnit, dtype_from_str
from repro.transfer.checksum import checksum as _buf_checksum

#: default row length (elements) of the ``int8`` wire codec: f32 scales
#: per 256 elements cost 4/256 extra bytes/element, i.e. a wire ratio of
#: (1 + 4/256)/4 = 0.2539 vs float32 weights (~3.9x) and 0.5078 vs bf16
#: (~2.0x). Matches the quant kernel's 256-row VMEM block geometry.
INT8_ROW_LEN = 256

#: dtypes the int8 codec quantizes; anything else rides as a tagged raw
#: passthrough (bit-exact) inside the same wire framing
_QUANTIZABLE: Dict[str, int] = {
    "float32": 1,
    "bfloat16": 2,
    "float16": 3,
    "float64": 4,
}
_DTYPE_FROM_CODE = {v: k for k, v in _QUANTIZABLE.items()}

#: int8 wire header: magic u32, version u8, flags u8 (bit0 = raw
#: passthrough), dtype code u8, reserved u8, row_len u32, orig_nbytes u64
_HDR = struct.Struct("<IBBBBIQ")
_MAGIC = 0x38515754  # "TWQ8"
_VERSION = 1
_FLAG_PASSTHROUGH = 1

#: delta wire header: magic u32, version u8, flags u8, dtype code u8,
#: reserved u8, row_len u32, orig_nbytes u64, base digest u64. The digest
#: is the Fletcher checksum of the exact base bytes the residuals were
#: computed against, so a stale or GC'd base fails loudly at decode
#: instead of being silently summed into garbage.
_D_HDR = struct.Struct("<IBBBBIQQ")
_D_MAGIC = 0x38445754  # "TWD8"
_D_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Int8Frame:
    """A validated view of one int8 wire frame (header already checked).

    ``parse_int8_frame`` produces these so consumers that want the frame's
    *components* — the fused dequant+gather path reads ``q``/``scales``
    directly into the kernel instead of materialising a decoded staging
    buffer — share the exact header/shape/scale validation of
    :meth:`Int8Codec.decode`.
    """

    #: element dtype name of the decoded payload; ``None`` for passthrough
    dtype: Optional[str]
    #: decoded payload size in bytes
    nbytes: int
    #: quantization row length in elements (meaningless for passthrough)
    row_len: int
    #: raw payload bytes for a passthrough frame, else ``None``
    passthrough: Optional[np.ndarray]
    #: int8 quantized values, flat, true length (no row padding); ``None``
    #: for passthrough
    q: Optional[np.ndarray]
    #: f32 per-row scales, one per (possibly partial) row; ``None`` for
    #: passthrough
    scales: Optional[np.ndarray]

    @property
    def is_passthrough(self) -> bool:
        return self.passthrough is not None


class CodecError(TensorHubError):
    """Malformed or inconsistent wire bytes (failed the wire-level
    scale/shape integrity check), or a codec misuse the data plane must
    refuse rather than corrupt bytes."""


class StaleBaseError(CodecError):
    """A delta frame's base-version digest does not match the bytes the
    destination holds (base evicted, GC'd, or never present). The
    transport catches this and transparently falls back to the base
    codec — it must never surface as source-corruption evidence."""


class WireCodec:
    """Interface: encode unit payloads into wire bytes and back.

    ``dtype`` is the payload's element dtype as a numpy dtype string
    (``None`` when unknown — e.g. a compacted bucket of mixed-dtype tiny
    tensors); codecs that need element semantics fall back to a tagged
    passthrough for such payloads.
    """

    name: str = "?"
    #: lossless codecs decode to the exact source bytes, so publish-time
    #: manifest checksums remain valid on the decoded payload
    lossless: bool = True
    #: codecs that encode residuals against a held base version; the
    #: transport passes ``base=`` (source snapshot on encode, destination
    #: held bytes on decode) only when this is set
    needs_base: bool = False

    def encode(self, payload: np.ndarray, dtype: Optional[str]) -> np.ndarray:
        """Flat uint8 payload -> flat uint8 wire bytes."""
        raise NotImplementedError

    def decode(self, wire: np.ndarray) -> np.ndarray:
        """Flat uint8 wire bytes -> flat uint8 decoded payload (the wire
        framing is self-describing)."""
        raise NotImplementedError

    def wire_nbytes(self, nbytes: int, dtype: Optional[str]) -> int:
        """Predicted wire size of an ``nbytes`` payload (exact for the
        real transport; the simulator derives fluid byte counts from it)."""
        raise NotImplementedError

    def row_bytes(self, dtype: Optional[str]) -> int:
        """Chunk-boundary granularity in payload bytes: sub-unit chunk
        offsets must be multiples of this for encode(chunk) to reproduce
        the whole-unit encoding row-for-row."""
        return 1


class RawCodec(WireCodec):
    """Identity codec: wire bytes ARE the payload bytes (no framing), so
    ``codec="raw"`` reproduces the pre-codec data plane bit-for-bit."""

    name = "raw"
    lossless = True

    def encode(self, payload: np.ndarray, dtype: Optional[str]) -> np.ndarray:
        return payload

    def decode(self, wire: np.ndarray) -> np.ndarray:
        return wire

    def wire_nbytes(self, nbytes: int, dtype: Optional[str]) -> int:
        return nbytes


class Int8Codec(WireCodec):
    """Symmetric per-row int8 quantization (q int8 + f32 scale per
    ``row_len`` elements), the ``kernels/quant`` scheme on the wire.

    Quantization is deterministic, so every replica that decodes the same
    published version over this codec holds byte-identical weights — the
    property that lets intra-DC readers chain raw pulls off an
    int8-seeded replica.
    """

    name = "int8"
    lossless = False

    def __init__(self, row_len: int = INT8_ROW_LEN, backend: str = "auto") -> None:
        if row_len <= 0:
            raise ValueError("row_len must be positive")
        self.row_len = row_len
        if backend not in ("auto", "numpy", "jax"):
            raise ValueError(f"unknown int8 backend {backend!r}")
        self._backend = backend
        self._jax_quant = None  # resolved lazily

    # -- backends ---------------------------------------------------------

    def _quant_rows(self, rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """f32 [R, L] -> (q int8 [R, L], scales f32 [R]). The jax path is
        the ``kernels/quant`` oracle (jitted; numerically identical to the
        Pallas kernel); NumPy reproduces it op-for-op (same IEEE ops, same
        round-half-to-even), so mixed deployments stay deterministic."""
        if self._backend != "numpy":
            fn = self._resolve_jax()
            if fn is not None:
                q, s = fn(rows)
                return np.asarray(q), np.asarray(s)
            if self._backend == "jax":
                raise CodecError("int8 codec: backend='jax' but JAX is unavailable")
        absmax = np.max(np.abs(rows), axis=1)
        scales = np.maximum(absmax / 127.0, 1e-12).astype(np.float32)
        q = np.clip(np.rint(rows / scales[:, None]), -127, 127).astype(np.int8)
        return q, scales

    def _resolve_jax(self):
        if self._jax_quant is None:
            try:
                import jax

                from repro.kernels.quant.ref import quantize_ref

                self._jax_quant = jax.jit(quantize_ref)
            except Exception:  # noqa: BLE001 — any import/backend failure
                self._jax_quant = False
        return self._jax_quant or None

    # -- framing ----------------------------------------------------------

    def _header(self, flags: int, dtype_code: int, nbytes: int) -> bytes:
        return _HDR.pack(_MAGIC, _VERSION, flags, dtype_code, 0, self.row_len, nbytes)

    def encode(self, payload: np.ndarray, dtype: Optional[str]) -> np.ndarray:
        flat = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        npdtype = None
        if dtype in _QUANTIZABLE:
            npdtype = dtype_from_str(dtype)
            if flat.nbytes % npdtype.itemsize:
                npdtype = None  # not a whole number of elements: passthrough
        if npdtype is None or flat.nbytes == 0:
            hdr = self._header(_FLAG_PASSTHROUGH, 0, flat.nbytes)
            return np.concatenate([np.frombuffer(hdr, np.uint8), flat])
        with np.errstate(over="ignore"):  # f32-overflow becomes inf, handled below
            x = flat.view(npdtype).astype(np.float32, copy=False)
        if not np.all(np.isfinite(x)):
            # NaN/Inf weights (transient RL loss spikes; f64 values that
            # overflow f32) would produce non-finite scales and fail the
            # decoder's integrity check — ship them bit-exact instead of
            # bricking the transfer
            hdr = self._header(_FLAG_PASSTHROUGH, 0, flat.nbytes)
            return np.concatenate([np.frombuffer(hdr, np.uint8), flat])
        n = x.size
        pad = (-n) % self.row_len
        if pad:
            x = np.concatenate([x, np.zeros(pad, np.float32)])
        q, scales = self._quant_rows(x.reshape(-1, self.row_len))
        hdr = self._header(0, _QUANTIZABLE[dtype], flat.nbytes)
        return np.concatenate(
            [
                np.frombuffer(hdr, np.uint8),
                scales.view(np.uint8).reshape(-1),
                # zero-padding elements are NOT wire bytes: send the true
                # payload only (the compressed_bytes clamp, on the wire)
                q.reshape(-1)[:n].view(np.uint8),
            ]
        )

    def decode(self, wire: np.ndarray) -> np.ndarray:
        frame = parse_int8_frame(wire)
        if frame.is_passthrough:
            return frame.passthrough
        npdtype = dtype_from_str(frame.dtype)
        n = frame.nbytes // npdtype.itemsize
        rows = frame.scales.size
        q = np.zeros(rows * frame.row_len, np.int8)
        q[:n] = frame.q
        x = (
            q.reshape(rows, frame.row_len).astype(np.float32)
            * frame.scales[:, None]
        ).reshape(-1)
        return np.ascontiguousarray(x[:n].astype(npdtype)).view(np.uint8).reshape(-1)

    def wire_nbytes(self, nbytes: int, dtype: Optional[str]) -> int:
        if dtype in _QUANTIZABLE and nbytes:
            itemsize = dtype_from_str(dtype).itemsize
            if nbytes % itemsize == 0:
                n = nbytes // itemsize
                return _HDR.size + 4 * (-(-n // self.row_len)) + n
        return _HDR.size + nbytes

    def row_bytes(self, dtype: Optional[str]) -> int:
        if dtype in _QUANTIZABLE:
            return self.row_len * dtype_from_str(dtype).itemsize
        return 1


class DeltaCodec(WireCodec):
    """Version-delta codec: int8-quantized residuals of v(n+1) against
    the destination's held v(n), ``delta:<base_codec>`` on the wire.

    The source encodes against its own snapshot of the base version,
    round-tripped through the base codec first so the residual is
    computed against the *exact bytes the destination holds* (an
    int8-seeded destination holds ``decode(encode(v_n))``, not ``v_n``).
    Rows whose payload bits are identical to the base snapshot — the
    common case for correlated RL weight versions — ship as a single bit
    in a kept-row bitmap; only changed rows carry (scale, q) residuals on
    the ``kernels/quant`` row grid. A skipped row decodes bit-exact from
    the destination's held bytes, so a delta pull of an unchanged row is
    byte-identical to what a fresh base-codec pull would have delivered.

    The frame header carries a digest of the base bytes; decode raises
    :class:`StaleBaseError` on mismatch (base evicted / GC'd / diverged)
    and the transport re-fetches via the base codec. Every fallback frame
    (no base at encode time, non-finite payload/base, unknown dtype) is a
    plain int8-framed wire — quantized for base ``int8``, tagged bit-exact
    passthrough for base ``raw`` — so decode sniffs the magic and never
    needs out-of-band signalling.
    """

    lossless = False  # kept rows carry quantized residuals
    needs_base = True

    def __init__(self, base_name: str, row_len: int = INT8_ROW_LEN) -> None:
        if base_name not in ("raw", "int8"):
            raise ValueError(
                f"delta base codec must be 'raw' or 'int8', got {base_name!r}"
            )
        self.base_name = base_name
        self.name = f"delta:{base_name}"
        self.row_len = row_len
        self._int8 = Int8Codec(row_len)

    # -- fallback framing (always int8-framed so decode can sniff) ---------

    def _fallback(self, flat: np.ndarray, dtype: Optional[str]) -> np.ndarray:
        if self.base_name == "int8":
            return self._int8.encode(flat, dtype)
        # base 'raw' must stay bit-exact: tagged passthrough frame
        hdr = _HDR.pack(
            _MAGIC, _VERSION, _FLAG_PASSTHROUGH, 0, 0, self.row_len, flat.nbytes
        )
        return np.concatenate([np.frombuffer(hdr, np.uint8), flat])

    def _base_estimate(
        self, base_flat: np.ndarray, dtype: Optional[str]
    ) -> np.ndarray:
        """The destination's held bytes, reconstructed source-side: the
        base-codec round-trip of the source's base snapshot."""
        if self.base_name == "raw":
            return base_flat
        return self._int8.decode(self._int8.encode(base_flat, dtype))

    # -- encode / decode ---------------------------------------------------

    def encode(
        self,
        payload: np.ndarray,
        dtype: Optional[str],
        base: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        flat = np.ascontiguousarray(payload).view(np.uint8).reshape(-1)
        npdtype = None
        if dtype in _QUANTIZABLE:
            npdtype = dtype_from_str(dtype)
            if flat.nbytes % npdtype.itemsize:
                npdtype = None
        if npdtype is None or flat.nbytes == 0 or base is None:
            return self._fallback(flat, dtype)
        base_flat = np.ascontiguousarray(base).view(np.uint8).reshape(-1)
        if base_flat.nbytes != flat.nbytes:
            return self._fallback(flat, dtype)
        with np.errstate(over="ignore"):
            x = flat.view(npdtype).astype(np.float32, copy=False)
        if not np.all(np.isfinite(x)):
            return self._fallback(flat, dtype)
        base_est = self._base_estimate(base_flat, dtype)
        with np.errstate(over="ignore"):
            b = base_est.view(npdtype).astype(np.float32, copy=False)
        if not np.all(np.isfinite(b)):
            return self._fallback(flat, dtype)
        n = x.size
        rows = -(-n // self.row_len)
        pad = rows * self.row_len - n
        rb = self.row_len * npdtype.itemsize
        # publisher-unchanged rows are detected against the base SNAPSHOT
        # (v_n's exact bytes): if v_{n+1}'s row bits equal v_n's, the
        # destination's held row (the base-codec round-trip of v_n) is
        # already exactly what a fresh base-codec pull of v_{n+1} would
        # deliver, so the row ships as a single bitmap bit
        pf = np.zeros((rows, rb), np.uint8)
        pf.reshape(-1)[: flat.nbytes] = flat
        bf = np.zeros((rows, rb), np.uint8)
        bf.reshape(-1)[: flat.nbytes] = base_flat
        bit_equal = np.all(pf == bf, axis=1)
        if pad:
            x = np.concatenate([x, np.zeros(pad, np.float32)])
            b = np.concatenate([b, np.zeros(pad, np.float32)])
        resid = (x - b).reshape(rows, self.row_len)
        q, scales = self._int8._quant_rows(resid)
        # rows whose residual quantizes to all-zero reconstruct exactly
        # the base bytes — skip them too (the all-zero-residual property)
        kept = (~bit_equal) & q.any(axis=1)
        kept_idx = np.flatnonzero(kept)
        q_kept = q[kept_idx].reshape(-1)
        if pad and kept.size and kept[-1]:
            # zero-padding elements are NOT wire bytes (compressed_bytes
            # clamp, as in the int8 frame)
            q_kept = q_kept[: q_kept.size - pad]
        digest = _buf_checksum(base_est) & 0xFFFFFFFFFFFFFFFF
        hdr = _D_HDR.pack(
            _D_MAGIC,
            _D_VERSION,
            0,
            _QUANTIZABLE[dtype],
            0,
            self.row_len,
            flat.nbytes,
            digest,
        )
        return np.concatenate(
            [
                np.frombuffer(hdr, np.uint8),
                np.packbits(kept.astype(np.uint8)),
                scales[kept_idx].view(np.uint8).reshape(-1),
                q_kept.view(np.uint8),
            ]
        )

    def decode(
        self, wire: np.ndarray, base: Optional[np.ndarray] = None
    ) -> np.ndarray:
        buf = np.ascontiguousarray(wire).view(np.uint8).reshape(-1)
        if buf.nbytes < _HDR.size:
            raise CodecError(f"delta wire: short buffer ({buf.nbytes}B < header)")
        (magic,) = struct.unpack("<I", buf[:4].tobytes())
        if magic == _MAGIC:
            # fallback frame: a plain int8-framed wire, no base required
            return self._int8.decode(buf)
        if buf.nbytes < _D_HDR.size:
            raise CodecError(f"delta wire: short buffer ({buf.nbytes}B < header)")
        magic, version, flags, dcode, _, row_len, orig_nbytes, digest = _D_HDR.unpack(
            buf[: _D_HDR.size].tobytes()
        )
        if magic != _D_MAGIC or version != _D_VERSION or flags != 0:
            raise CodecError(
                f"delta wire: bad framing (magic {magic:#x}, version {version}, "
                f"flags {flags})"
            )
        dtype = _DTYPE_FROM_CODE.get(dcode)
        if dtype is None:
            raise CodecError(f"delta wire: unknown dtype code {dcode}")
        npdtype = dtype_from_str(dtype)
        if row_len <= 0 or orig_nbytes % npdtype.itemsize or orig_nbytes == 0:
            raise CodecError(
                f"delta wire: inconsistent shape (row_len {row_len}, "
                f"{orig_nbytes}B of {dtype})"
            )
        if base is None:
            raise StaleBaseError(
                "delta wire: destination holds no base version for this unit"
            )
        base_flat = np.ascontiguousarray(base).view(np.uint8).reshape(-1)
        if base_flat.nbytes != orig_nbytes:
            raise StaleBaseError(
                f"delta wire: held base is {base_flat.nbytes}B, frame encodes "
                f"residuals against {orig_nbytes}B"
            )
        if (_buf_checksum(base_flat) & 0xFFFFFFFFFFFFFFFF) != digest:
            raise StaleBaseError(
                "delta wire: base-version digest mismatch (base evicted, GC'd "
                "or diverged) — refusing to sum residuals against wrong bytes"
            )
        n = orig_nbytes // npdtype.itemsize
        rows = -(-n // row_len)
        pad = rows * row_len - n
        bitmap_nbytes = -(-rows // 8)
        body = buf[_D_HDR.size :]
        if body.nbytes < bitmap_nbytes:
            raise CodecError(
                f"delta wire: {body.nbytes}B body < {bitmap_nbytes}B kept-row bitmap"
            )
        kept = np.unpackbits(body[:bitmap_nbytes], count=rows).astype(bool)
        kept_idx = np.flatnonzero(kept)
        k = kept_idx.size
        q_len = k * row_len - (pad if (k and kept[-1]) else 0)
        if body.nbytes != bitmap_nbytes + 4 * k + q_len:
            raise CodecError(
                f"delta wire: {body.nbytes}B body != {bitmap_nbytes}B bitmap + "
                f"{4 * k}B scales + {q_len}B q for {k} kept rows"
            )
        rb = row_len * npdtype.itemsize
        out = np.zeros((rows, rb), np.uint8)
        out.reshape(-1)[:orig_nbytes] = base_flat
        if k:
            scales = body[bitmap_nbytes : bitmap_nbytes + 4 * k].view(np.float32)
            if not np.all(np.isfinite(scales)) or np.any(scales <= 0):
                raise CodecError("delta wire: non-finite or non-positive scales")
            q = np.zeros(k * row_len, np.int8)
            q[:q_len] = body[bitmap_nbytes + 4 * k :].view(np.int8)
            with np.errstate(over="ignore"):
                b = out.view(npdtype)[kept_idx].astype(np.float32)
            recon = b + q.reshape(k, row_len).astype(np.float32) * scales[:, None]
            out[kept_idx] = (
                np.ascontiguousarray(recon.astype(npdtype)).view(np.uint8)
            )
        return np.ascontiguousarray(out.reshape(-1)[:orig_nbytes])

    # -- sizing ------------------------------------------------------------

    def wire_nbytes_at(
        self, nbytes: int, dtype: Optional[str], kept_frac: float
    ) -> int:
        """Predicted wire size when ``kept_frac`` of the rows changed
        between versions (the simulator's per-manifest delta ratio)."""
        if dtype in _QUANTIZABLE and nbytes:
            itemsize = dtype_from_str(dtype).itemsize
            if nbytes % itemsize == 0:
                n = nbytes // itemsize
                rows = -(-n // self.row_len)
                frac = min(1.0, max(0.0, float(kept_frac)))
                k = int(round(rows * frac))
                return (
                    _D_HDR.size
                    + -(-rows // 8)
                    + 4 * k
                    + min(n, k * self.row_len)
                )
        return _HDR.size + nbytes

    def wire_nbytes(self, nbytes: int, dtype: Optional[str]) -> int:
        return self.wire_nbytes_at(nbytes, dtype, 1.0)

    def row_bytes(self, dtype: Optional[str]) -> int:
        return self._int8.row_bytes(dtype)


class FixedRatioCodec(WireCodec):
    """Fluid-byte modeling codec: scales wire bytes by a fixed ratio.

    This is the migration target of the simulator's deprecated
    ``tcp_compression`` scalar — it exists so legacy callers keep their
    exact byte accounting. It carries no real encoding, so the threaded
    transport refuses it.
    """

    lossless = True

    def __init__(self, ratio: float) -> None:
        if not (0.0 < ratio):
            raise ValueError(f"fixed codec ratio must be positive, got {ratio}")
        self.ratio = float(ratio)
        self.name = f"fixed:{self.ratio!r}"

    def encode(self, payload: np.ndarray, dtype: Optional[str]) -> np.ndarray:
        raise CodecError(
            "fixed-ratio codecs model wire bytes in the simulator only; "
            "the real transport cannot encode with one"
        )

    def decode(self, wire: np.ndarray) -> np.ndarray:
        raise CodecError(
            "fixed-ratio codecs model wire bytes in the simulator only; "
            "the real transport cannot decode with one"
        )

    def wire_nbytes(self, nbytes: int, dtype: Optional[str]) -> int:
        return int(round(nbytes * self.ratio))


_REGISTRY: Dict[str, WireCodec] = {}


def get_codec(name: str) -> WireCodec:
    """Resolve a negotiated codec name (``raw``, ``int8``,
    ``delta:<base>``, ``fixed:<ratio>``). Raises :class:`TensorHubError`
    for unknown names so a bad negotiation fails at plan time, not
    mid-transfer."""
    c = _REGISTRY.get(name)
    if c is not None:
        return c
    if name.startswith("delta:"):
        try:
            c = DeltaCodec(name[len("delta:") :])
        except ValueError as e:
            raise TensorHubError(f"bad delta codec {name!r}: {e}") from None
        _REGISTRY[name] = c
        return c
    if name.startswith("fixed:"):
        try:
            c = FixedRatioCodec(float(name[len("fixed:") :]))
        except ValueError as e:
            raise TensorHubError(f"bad fixed-ratio codec {name!r}: {e}") from None
        _REGISTRY[name] = c
        return c
    raise TensorHubError(f"unknown wire codec {name!r}")


for _c in (RawCodec(), Int8Codec()):
    _REGISTRY[_c.name] = _c


# ---------------------------------------------------------------------------
# shared helpers for the data planes
# ---------------------------------------------------------------------------


def parse_int8_frame(wire: np.ndarray) -> Int8Frame:
    """Validate an int8 wire frame and return its components without
    dequantizing. :meth:`Int8Codec.decode` is ``parse + dequant``; the
    fused dequant+gather path parses frames and feeds ``q``/``scales``
    straight into the kernel."""
    buf = np.ascontiguousarray(wire).view(np.uint8).reshape(-1)
    if buf.nbytes < _HDR.size:
        raise CodecError(f"int8 wire: short buffer ({buf.nbytes}B < header)")
    magic, version, flags, dcode, _, row_len, orig_nbytes = _HDR.unpack(
        buf[: _HDR.size].tobytes()
    )
    if magic != _MAGIC or version != _VERSION:
        raise CodecError(
            f"int8 wire: bad framing (magic {magic:#x}, version {version})"
        )
    body = buf[_HDR.size :]
    if flags & _FLAG_PASSTHROUGH:
        if body.nbytes != orig_nbytes:
            raise CodecError(
                f"int8 wire: passthrough length {body.nbytes}B != "
                f"declared {orig_nbytes}B"
            )
        return Int8Frame(
            dtype=None,
            nbytes=orig_nbytes,
            row_len=row_len,
            passthrough=body,
            q=None,
            scales=None,
        )
    dtype = _DTYPE_FROM_CODE.get(dcode)
    if dtype is None:
        raise CodecError(f"int8 wire: unknown dtype code {dcode}")
    npdtype = dtype_from_str(dtype)
    if row_len <= 0 or orig_nbytes % npdtype.itemsize:
        raise CodecError(
            f"int8 wire: inconsistent shape (row_len {row_len}, "
            f"{orig_nbytes}B of {dtype})"
        )
    n = orig_nbytes // npdtype.itemsize
    rows = -(-n // row_len)
    if body.nbytes != 4 * rows + n:
        raise CodecError(
            f"int8 wire: {body.nbytes}B body != {4 * rows}B scales + "
            f"{n}B q for {n} x {dtype}"
        )
    scales = body[: 4 * rows].view(np.float32)
    if not np.all(np.isfinite(scales)) or np.any(scales <= 0):
        raise CodecError("int8 wire: non-finite or non-positive scales")
    return Int8Frame(
        dtype=dtype,
        nbytes=orig_nbytes,
        row_len=row_len,
        passthrough=None,
        q=body[4 * rows :].view(np.int8),
        scales=scales,
    )


def reshard_wire_codec(name: str) -> str:
    """THE cross-layout codec policy point: the wire codec a resharded
    (or aliased-layout) cross-DC slice carries, given the link class's
    negotiated codec ``name``.

    ``delta:<base>`` collapses to its base codec — residuals are encoded
    against the destination's held bytes *in the destination's layout*,
    which a cross-layout source does not hold, so there is no valid base
    for a reshard interval. Everything else (``raw``, ``int8``,
    ``fixed:*`` for fluid modeling) passes through unchanged: row-grid
    planned intervals carry it end to end.

    Every reshard path — server negotiation, both data planes, and the
    networked transport — derives its codec through this function; the
    five scattered raw-only guards this replaces are gone.
    """
    if name.startswith("delta:"):
        return name[len("delta:") :]
    return name


def quantizable(dtype: Optional[str]) -> bool:
    """True when the int8 codec actually quantizes this element dtype
    (anything else rides as a tagged passthrough, same bytes + header)."""
    return dtype in _QUANTIZABLE


def manifest_quantizable(manifest) -> bool:
    """True when at least one transfer unit of the shard manifest carries
    a quantizable payload — i.e. negotiating a lossy codec for this source
    can actually shrink wire bytes. A manifest of opaque/integer payloads
    would frame every unit as passthrough for zero gain; the server
    degrades such plans to ``raw`` (and ticks ``codec_degrades``)."""
    tensors = {t.name: t for t in manifest.tensors}
    return any(
        quantizable(unit_wire_dtype(tensors, u)) for u in manifest.units
    )


def unit_wire_dtype(
    tensors: Mapping[str, TensorMeta], unit: TransferUnit
) -> Optional[str]:
    """Element dtype of a transfer unit's payload: the tensor's dtype for
    a plain unit, the members' common dtype for a homogeneous compacted
    bucket, ``None`` (codecs pass through) when members mix dtypes or a
    member is unknown."""
    if not unit.is_compact:
        t = tensors.get(unit.name)
        return None if t is None else t.dtype
    dtype: Optional[str] = None
    for name in unit.members:
        t = tensors.get(name)
        if t is None:
            return None
        if dtype is None:
            dtype = t.dtype
        elif t.dtype != dtype:
            return None
    return dtype


def wire_ratio(
    codec: WireCodec,
    unit_sizes: Iterable[int],
    dtype: Optional[str],
    *,
    delta_kept_frac: float = 1.0,
) -> float:
    """Wire-bytes / payload-bytes of one shard manifest under ``codec``
    (the simulator's fluid byte multiplier, computed from the codec's
    actual size formula rather than a hand-set scalar).

    ``delta_kept_frac`` models how correlated successive versions are for
    a :class:`DeltaCodec`: the fraction of quantization rows that changed
    between the base and the shipped version (1.0 = every row changed,
    the codec's worst case). Ignored for non-delta codecs.
    """
    if isinstance(codec, FixedRatioCodec):
        return codec.ratio
    sizes = [int(n) for n in unit_sizes]
    total = sum(sizes)
    if total <= 0:
        return 1.0
    if isinstance(codec, DeltaCodec):
        return (
            sum(codec.wire_nbytes_at(n, dtype, delta_kept_frac) for n in sizes)
            / total
        )
    return sum(codec.wire_nbytes(n, dtype) for n in sizes) / total


def slice_codecs(assignment) -> set:
    """All codec names an assignment may use (top-level + per-slice)."""
    out = {assignment.codec}
    for s in assignment.sources:
        out.add(s.codec)
    return out


def assignment_lossy(assignment) -> bool:
    """True when any negotiated codec in the plan is lossy — the decoded
    bytes then differ from the publisher's, so the destination must
    (re)register its own manifest checksums."""
    return any(not get_codec(n).lossless for n in slice_codecs(assignment))


def codec_attrs(name: str) -> dict:
    """Span attributes describing a negotiated codec — attached to flow
    and pull spans by both data planes so traces carry the wire format
    alongside bytes/source/link-class."""
    c = get_codec(name)
    return {"codec": c.name, "lossless": c.lossless}
