"""End-to-end transfer checksums (4.6).

TensorHub attaches a per-unit checksum to every published reference and
validates it after transfer. We use a position-weighted Fletcher-style fold
over 32-bit words:

    s1 = sum(w_i)                 mod 2^32
    s2 = sum(((i & 0xffff)+1) * w_i) mod 2^32
    checksum = (s2 << 32) | s1

The position weight catches reordering/offset bugs that a plain sum misses.
A non-empty buffer whose fold lands on exactly 0 is remapped to
:data:`ZERO_STANDIN`: the transfer layer uses checksum 0 as the
"verification disabled" sentinel (divergent-manifest pulls), and a
colliding real payload — e.g. symmetric constant data whose weighted
sums cancel — must not silently disarm end-to-end verification.
All arithmetic is mod-2^32, so the *same* value is computed by

* this NumPy implementation (host side, used by the real transport),
* the pure-jnp oracle ``repro.kernels.checksum.ref`` (int32 wraparound), and
* the Pallas TPU kernel ``repro.kernels.checksum`` (device side, overlappable
  with the RDMA transfer, per 4.6).
"""

from __future__ import annotations

import numpy as np

_MASK32 = np.uint64(0xFFFFFFFF)

#: stand-in for a non-empty buffer folding to exactly 0 — any fixed
#: non-zero value works (the induced collision class is the same
#: ~2^-64 as the fold itself); shared with ``kernels.checksum.fold64``
ZERO_STANDIN = 0x5EED_0000_0000_5EED


def _as_words(buf: bytes | bytearray | memoryview | np.ndarray) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        raw = np.ascontiguousarray(buf).view(np.uint8).reshape(-1)
    else:
        raw = np.frombuffer(buf, dtype=np.uint8)
    pad = (-raw.size) % 4
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, dtype=np.uint8)])
    return raw.view(np.uint32)


def checksum(buf: bytes | bytearray | memoryview | np.ndarray) -> int:
    """64-bit fold checksum of a byte buffer (see module docstring)."""
    words = _as_words(buf).astype(np.uint64)
    n = words.size
    if n == 0:
        return 0
    idx = np.arange(n, dtype=np.uint64)
    weights = (idx & np.uint64(0xFFFF)) + np.uint64(1)
    s1 = int(words.sum() & _MASK32)
    s2 = int((words * weights).sum() & _MASK32)
    return ((s2 << 32) | s1) or ZERO_STANDIN


def combine(chunks: list[int]) -> int:
    """Order-sensitive combination of per-chunk checksums (for chunked
    verification paths): a second-level fold over the chunk checksums."""
    acc = np.uint64(0)
    for i, c in enumerate(chunks):
        w = np.uint64(c & 0xFFFFFFFFFFFFFFFF)
        acc = (acc + (np.uint64((i & 0xFFFF) + 1) * (w ^ (w >> np.uint64(32))))) & np.uint64(
            0xFFFFFFFFFFFFFFFF
        )
    return int(acc)
