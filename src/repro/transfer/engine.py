"""Transfer engine: worker-side data plane (4.3.2).

``WorkerStore`` is the per-worker registry of weight buffers — the memory
that the reference server hands out references *to*. The store builds the
transfer-unit schedule (tiny-tensor compaction, 4.3.2) and serves/absorbs
unit payloads.

``Transport`` abstracts the wire. The paper's engine has three modes (RDMA
direct / RDMA copy / TCP) built on Mooncake; in this offline repo:

* :class:`LocalTransport` — real in-process byte copies between stores.
  Used by tests and examples; exercises the exact same control plane.
* the event-driven simulated network (``repro.transfer.simnet``) — used by
  the benchmark harness to reproduce the paper's timing behaviour.
* a production TPU backend would implement ``Transport`` over
  ``jax.experimental.transfer`` cross-slice DMA; nothing above this
  interface would change.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import (  # noqa: F401  (TransportError re-exported:
    # it lived here before joining the error taxonomy in core.errors)
    ChecksumError,
    NotRegisteredError,
    TensorHubError,
    TransportError,
)
from repro.core.meta import (  # noqa: F401  (DEFAULT_* re-exported)
    DEFAULT_CHUNK_BYTES,
    DEFAULT_WINDOW,
    ShardManifest,
    TensorMeta,
    TransferUnit,
    build_units,
)
from repro.obs import telemetry as obs
from repro.transfer import checksum as checksum_lib
from repro.transfer import codec as codec_lib

#: per-tensor layout descriptor: (global_shape, offset) — see
#: ``repro.resharding`` for the format
LayoutEntry = Tuple[Tuple[int, ...], Tuple[int, ...]]


def tensor_meta(
    name: str, arr: np.ndarray, layout: Optional[LayoutEntry] = None
) -> TensorMeta:
    gshape, offset = layout if layout is not None else (None, None)
    return TensorMeta(
        name=name,
        shape=tuple(arr.shape),
        dtype=str(arr.dtype),
        nbytes=arr.nbytes,
        global_shape=gshape,
        offset=offset,
    )


class WorkerStore:
    """Registered weight buffers of one shard-owning worker.

    Buffers are NumPy arrays (the CPU stand-in for GPU/TPU HBM). The store
    is thread-safe: publishes are immutable by contract, so readers take no
    lock on the bytes themselves — only registry mutations lock, mirroring
    one-sided RDMA semantics.
    """

    def __init__(self, worker_id: str) -> None:
        self.worker_id = worker_id
        self._lock = threading.Lock()
        self._buffers: Dict[str, np.ndarray] = {}
        self._layouts: Dict[str, LayoutEntry] = {}
        self._units: List[TransferUnit] = []
        self._metas: List[TensorMeta] = []
        self._meta_by_name: Dict[str, TensorMeta] = {}
        self._unit_of: Dict[str, int] = {}
        #: simulate preemption: a failed store refuses all reads
        self.failed = False
        #: delta-transfer base snapshot: the most recent published
        #: version's unit payloads, captured at unpublish/update time so
        #: this worker can serve (or receive) int8 residuals against it.
        #: Deliberately NOT cleared by ``register`` — the publisher
        #: re-registers v(n+1) buffers between unpublish and publish, and
        #: the snapshot of v(n) must survive that to serve residuals.
        self._base_version: Optional[int] = None
        self._base_units: Dict[str, np.ndarray] = {}
        #: swarm replication served-prefix watermark: while this shard is
        #: itself mid-replication, only units ``[0, serving_prefix)`` hold
        #: final bytes and may be served to swarm readers. ``None`` means
        #: unrestricted (publishers, completed replicas). The owner's pull
        #: loop advances it *before* reporting progress to the server, so
        #: any unit the scheduler shows as available is readable here — a
        #: read past the watermark is a planner/claim bug, not a race.
        self.serving_prefix: Optional[int] = None

    # -- registration ----------------------------------------------------------

    def register(
        self,
        named_tensors: Mapping[str, np.ndarray],
        *,
        layout: Optional[Mapping[str, LayoutEntry]] = None,
    ) -> None:
        """Register weight buffers; ``layout`` optionally stamps each
        tensor's layout descriptor (global shape + slice offset) onto its
        metadata so cross-layout readers can reshard from this shard.

        Registration asserts ownership of the buffers, so any served-prefix
        watermark left behind by an earlier aborted pull is lifted — a
        stale watermark would otherwise poison every later version served
        from this store."""
        self.serving_prefix = None
        with self._lock:
            for name, arr in named_tensors.items():
                buf = np.ascontiguousarray(arr)
                if not buf.flags.writeable:  # e.g. np.asarray(jax_array) views
                    buf = buf.copy()
                self._buffers[name] = buf
                if layout is not None and name in layout:
                    self._layouts[name] = layout[name]
            self._rebuild_units()

    def unregister(self, names: Optional[Sequence[str]] = None) -> None:
        with self._lock:
            if names is None:
                self._buffers.clear()
                self._layouts.clear()
            else:
                for n in names:
                    self._buffers.pop(n, None)
                    self._layouts.pop(n, None)
            self._rebuild_units()

    def _rebuild_units(self) -> None:
        self._metas = [
            tensor_meta(n, a, self._layouts.get(n)) for n, a in self._buffers.items()
        ]
        self._meta_by_name = {m.name: m for m in self._metas}
        self._units = build_units(self._metas)
        self._unit_of = {}
        for u in self._units:
            self._unit_of[u.name] = u.index
            for m in u.members:
                self._unit_of[m] = u.index

    def unit_dtype(self, unit: TransferUnit) -> Optional[str]:
        """Element dtype of a unit's payload (None for mixed-dtype compact
        buckets) — what a wire codec needs to quantize the bytes."""
        return codec_lib.unit_wire_dtype(self._meta_by_name, unit)

    def _check_served(self, unit_index: int, what: str) -> None:
        """Never-read-past-source-prefix guard (swarm replication)."""
        sp = self.serving_prefix
        if sp is not None and unit_index >= sp:
            raise TensorHubError(
                f"{self.worker_id}: read of {what} (unit {unit_index}) beyond "
                f"the served prefix [0, {sp}) — the bytes there are not final; "
                "swarm readers must gate on the source's progress counter"
            )

    @property
    def layouts(self) -> Dict[str, LayoutEntry]:
        return dict(self._layouts)

    @property
    def units(self) -> List[TransferUnit]:
        return list(self._units)

    @property
    def metas(self) -> List[TensorMeta]:
        return list(self._metas)

    @property
    def total_bytes(self) -> int:
        return sum(u.nbytes for u in self._units)

    def tensors(self) -> Dict[str, np.ndarray]:
        with self._lock:
            return dict(self._buffers)

    def get(self, name: str) -> np.ndarray:
        return self._buffers[name]

    # -- manifest / checksums ----------------------------------------------------

    def build_manifest(self, *, with_checksums: bool = True) -> ShardManifest:
        if not self._buffers:
            raise NotRegisteredError(f"{self.worker_id}: no tensors registered")
        sums = tuple(
            checksum_lib.checksum(self._gather_unit(u)) if with_checksums else 0
            for u in self._units
        )
        return ShardManifest(
            tensors=tuple(self._metas), units=tuple(self._units), checksums=sums
        )

    # -- unit payload serve/absorb ------------------------------------------------

    def read_unit(self, unit: TransferUnit) -> np.ndarray:
        """Serve one transfer unit as a flat byte array (zero-copy for large
        tensors; gather-into-staging for compacted buckets — the paper's
        RDMA-copy path). Transport-facing: refuses reads of units beyond
        the served prefix while this shard is itself mid-replication."""
        if self.failed:
            raise TransportError(f"{self.worker_id} is dead")
        self._check_served(unit.index, unit.name)
        return self._gather_unit(unit)

    def _gather_unit(self, unit: TransferUnit) -> np.ndarray:
        """Owner-local unit gather (manifest checksums, snapshots): not
        prefix-guarded — the owner may always see its own buffers."""
        if not unit.is_compact:
            arr = self._buffers.get(unit.name)
            if arr is None:
                raise NotRegisteredError(f"{self.worker_id}: unknown tensor {unit.name}")
            return arr.view(np.uint8).reshape(-1)
        staging = np.empty(unit.nbytes, dtype=np.uint8)
        for name, off, nbytes in unit.layout:
            src = self._buffers[name].view(np.uint8).reshape(-1)
            staging[off : off + nbytes] = src
        return staging

    def write_unit(self, unit: TransferUnit, payload: np.ndarray) -> None:
        """Absorb one transfer unit into the registered buffers in place.

        Like the read paths, a failed (preempted) store refuses the
        write: a dead worker silently accepting bytes would let a pull
        "complete" into memory nobody will ever serve or use."""
        if self.failed:
            raise TransportError(f"{self.worker_id} is dead")
        if payload.nbytes != unit.nbytes:
            raise TensorHubError(
                f"unit {unit.name}: payload {payload.nbytes}B != expected {unit.nbytes}B"
            )
        flat = payload.view(np.uint8).reshape(-1)
        if not unit.is_compact:
            dst = self._buffers.get(unit.name)
            if dst is None:
                raise NotRegisteredError(f"{self.worker_id}: unknown tensor {unit.name}")
            dst.view(np.uint8).reshape(-1)[:] = flat
            return
        for name, off, nbytes in unit.layout:
            dst = self._buffers[name].view(np.uint8).reshape(-1)
            dst[:] = flat[off : off + nbytes]

    # -- sub-unit byte ranges (cross-layout resharding) ---------------------------

    def read_range(self, name: str, offset: int, nbytes: int) -> np.ndarray:
        """Serve a byte range of one tensor's local buffer (zero-copy
        view; the transport makes the wire copy). The striped reads of a
        reshard plan are exactly these one-sided range reads."""
        if self.failed:
            raise TransportError(f"{self.worker_id} is dead")
        idx = self._unit_of.get(name)
        if idx is not None:
            self._check_served(idx, name)
        arr = self._buffers.get(name)
        if arr is None:
            raise NotRegisteredError(f"{self.worker_id}: unknown tensor {name}")
        if offset < 0 or offset + nbytes > arr.nbytes:
            raise TensorHubError(
                f"{self.worker_id}/{name}: range [{offset}, {offset + nbytes}) "
                f"exceeds buffer of {arr.nbytes}B"
            )
        return arr.view(np.uint8).reshape(-1)[offset : offset + nbytes]

    def write_range(self, name: str, offset: int, payload: np.ndarray) -> None:
        """Absorb a byte range (reshard staging writes). Refuses writes on
        a failed store, mirroring ``read_range`` — a dead worker must not
        silently accept bytes."""
        if self.failed:
            raise TransportError(f"{self.worker_id} is dead")
        dst = self._buffers.get(name)
        if dst is None:
            raise NotRegisteredError(f"{self.worker_id}: unknown tensor {name}")
        flat = payload.view(np.uint8).reshape(-1)
        if offset < 0 or offset + flat.nbytes > dst.nbytes:
            raise TensorHubError(
                f"{self.worker_id}/{name}: write [{offset}, {offset + flat.nbytes}) "
                f"exceeds buffer of {dst.nbytes}B"
            )
        dst.view(np.uint8).reshape(-1)[offset : offset + flat.nbytes] = flat

    # -- delta-transfer base snapshots --------------------------------------------

    def snapshot_base(self, version: int) -> None:
        """Snapshot the currently registered unit payloads as the delta
        base for ``version``. Called by the client when a published
        version is retired (publisher unpublish) or superseded locally
        (destination about to pull an update) — both sides of a
        ``delta:<base>`` transfer encode/decode against these bytes.
        Only the most recent snapshot is kept (one version of history,
        matching the server's prior-version bookkeeping)."""
        with self._lock:
            self._base_version = version
            self._base_units = {
                u.name: self._gather_unit(u).copy() for u in self._units
            }

    @property
    def base_version(self) -> Optional[int]:
        return self._base_version

    def base_unit(self, unit: TransferUnit) -> Optional[np.ndarray]:
        """The snapshotted base payload for ``unit``, or ``None`` when no
        matching snapshot exists (name or size mismatch after a model
        change — the codec then falls back to a base-codec frame)."""
        arr = self._base_units.get(unit.name)
        if arr is None or arr.nbytes != unit.nbytes:
            return None
        return arr

    def drop_base(self) -> None:
        """Evict the delta base snapshot (GC / memory-pressure path; also
        what tests use to model a destination whose base is gone)."""
        with self._lock:
            self._base_version = None
            self._base_units = {}

    # -- offload ------------------------------------------------------------------

    def snapshot_to(self, other: "WorkerStore") -> None:
        """Copy all registered buffers into another store (the CPU offload
        path of the retention protocol, 3.3 — PCIe copy in the paper)."""
        with self._lock:
            other.register(
                {n: a.copy() for n, a in self._buffers.items()},
                layout=dict(self._layouts),
            )


class WorkerRegistry:
    """In-process lookup: (replica, shard_idx) -> WorkerStore.

    Stands in for the RDMA address exchange: the server hands out a replica
    name, the transport resolves it to memory it can read.
    """

    def __init__(self) -> None:
        self._stores: Dict[Tuple[str, int], WorkerStore] = {}
        self._lock = threading.Lock()

    def add(self, replica: str, shard_idx: int, store: WorkerStore) -> None:
        with self._lock:
            self._stores[(replica, shard_idx)] = store

    def remove(self, replica: str, shard_idx: int) -> None:
        with self._lock:
            self._stores.pop((replica, shard_idx), None)

    def get(self, replica: str, shard_idx: int) -> WorkerStore:
        with self._lock:
            store = self._stores.get((replica, shard_idx))
        if store is None or store.failed:
            raise TransportError(f"no live store for {replica}/shard{shard_idx}")
        return store

    def lookup(self, replica: str, shard_idx: int) -> Optional[WorkerStore]:
        """The registered store, live or failed, or ``None`` when this
        process holds no entry at all. The networked transport uses the
        distinction: a locally-registered-but-dead store must fail fast
        (as :meth:`get` does), while an *absent* one means the source
        lives in another process and the read goes over the wire."""
        with self._lock:
            return self._stores.get((replica, shard_idx))

    def fail_replica(self, replica: str) -> None:
        """Kill every shard of a replica (spot preemption in tests)."""
        with self._lock:
            for (r, _), store in self._stores.items():
                if r == replica:
                    store.failed = True


class LocalTransport:
    """Real byte-copy transport between in-process stores."""

    def __init__(
        self,
        registry: WorkerRegistry,
        *,
        verify_checksums: bool = True,
        recorder: Optional[obs.Recorder] = None,
        faults=None,
    ) -> None:
        self.registry = registry
        self.verify_checksums = verify_checksums
        self.recorder = obs.DISABLED if recorder is None else recorder
        #: optional gray-fault injector (``repro.transfer.faults``):
        #: consulted at the top of every read (hang/slow/flaky) and on
        #: served payloads ahead of verification (corrupt byte-flips)
        self.faults = faults
        self.bytes_moved = 0
        # Per-link-class byte accounting, mirroring the simulator's link
        # tags ("rdma" intra-DC, "vpc_up" WAN, "pcie" offload): wire
        # bytes are what the NIC carried (post-codec), decoded bytes the
        # payload delivered. Always on — the cross-DC benchmarks assert
        # sim-vs-threaded parity from these counters.
        self.wire_bytes: Dict[str, int] = {}
        self.decoded_bytes: Dict[str, int] = {}
        #: delta transfers that hit a stale/evicted destination base and
        #: transparently re-fetched through the base codec (the wire
        #: carried both frames; final bytes are byte-identical to a plain
        #: base-codec pull)
        self.delta_stale_fallbacks = 0
        self._acct_lock = threading.Lock()

    def _fault_read(self, src_replica: str, shard_idx: int) -> None:
        if self.faults is not None:
            self.faults.before_read(src_replica, shard_idx)

    def _fault_flip(self, src_replica: str, payload: np.ndarray, verified: bool) -> None:
        # only flip bytes a checksum will catch: an unverified flip would
        # silently propagate instead of exercising the reject path
        if verified and self.faults is not None and self.faults.corrupts(src_replica):
            self.faults.flip(payload)

    def _fault_truncate(self, src_replica: str, wire: np.ndarray) -> np.ndarray:
        """Torn-frame injection on codec wires: drop the frame's tail so
        the destination's decode fails the wire-level size integrity
        check (a CodecError, not a ChecksumError — the decode-failure
        healing path)."""
        if self.faults is not None and self.faults.truncates(src_replica):
            return wire[: wire.nbytes - max(1, wire.nbytes // 4)]
        return wire

    @staticmethod
    def _dest_base(dst_store: WorkerStore, unit: TransferUnit) -> Optional[np.ndarray]:
        """The destination's currently-held bytes for ``unit`` — the base
        a delta frame's residuals are summed against. ``None`` when the
        destination has no matching buffers (fresh replica, model
        change); the codec's digest check catches every subtler mismatch."""
        try:
            return dst_store._gather_unit(unit)
        except (TensorHubError, KeyError):
            return None

    def _account(self, link_class: str, wire_nbytes: int, decoded_nbytes: int) -> None:
        # windowed pulls share one transport across span-worker threads
        with self._acct_lock:
            self.bytes_moved += wire_nbytes
            self.wire_bytes[link_class] = self.wire_bytes.get(link_class, 0) + wire_nbytes
            self.decoded_bytes[link_class] = (
                self.decoded_bytes.get(link_class, 0) + decoded_nbytes
            )

    def pull_unit(
        self,
        src_replica: str,
        shard_idx: int,
        unit: TransferUnit,
        expected_checksum: int,
        dst_store: WorkerStore,
        codec: str = "raw",
        link_class: str = "rdma",
        track: Optional[str] = None,
    ) -> None:
        """Pull one whole transfer unit through the negotiated wire codec.

        ``codec="raw"`` is the pre-codec wire bit-for-bit: payload bytes
        move unframed and are verified against the *publish-time* manifest
        checksum. A non-raw codec encodes at the source and decodes at the
        destination; end-to-end verification then runs over the **decoded**
        bytes — the source checksums ``decode(encode(payload))`` at read
        time (a lossy codec's output cannot match the publish-time sum)
        and the reader re-verifies after the wire copy, the same transit
        contract as :meth:`read_unit_range`. ``bytes_moved`` counts wire
        bytes, i.e. what the NIC actually carried."""
        src = self.registry.get(src_replica, shard_idx)
        self._fault_read(src_replica, shard_idx)
        cdc = codec_lib.get_codec(codec)
        rec = self.recorder
        if codec == "raw":
            payload = src.read_unit(unit).copy()  # the wire copy
            self._fault_flip(
                src_replica,
                payload,
                self.verify_checksums and bool(expected_checksum),
            )
            if self.verify_checksums and expected_checksum:
                t0 = rec.clock() if rec.enabled else 0.0
                got = checksum_lib.checksum(payload)
                if rec.enabled:
                    rec.counter_add(obs.CTR_VERIFY, rec.clock() - t0)
                    if track is not None:
                        rec.event("verify", track=track, unit=unit.name)
                if got != expected_checksum:
                    raise ChecksumError(
                        f"unit {unit.name} from {src_replica}/shard{shard_idx}: "
                        f"checksum {got:#x} != expected {expected_checksum:#x}"
                    )
            dst_store.write_unit(unit, payload)
            self._account(link_class, unit.nbytes, unit.nbytes)
            return
        t0 = rec.clock() if rec.enabled else 0.0
        raw_payload = src.read_unit(unit)
        dtype = src.unit_dtype(unit)
        if getattr(cdc, "needs_base", False):
            # delta codec: encode residuals against the SOURCE's snapshot
            # of the base version, decode them against the DESTINATION's
            # held bytes. A stale/evicted destination base raises
            # StaleBaseError, handled HERE — the source is not at fault,
            # so it must never surface as corruption evidence; the unit
            # transparently re-ships as a base-codec frame (both frames
            # crossed the wire, and accounting says so).
            wire = cdc.encode(raw_payload, dtype, base=src.base_unit(unit))
            wire = self._fault_truncate(src_replica, wire)
            wire_nbytes = wire.nbytes
            try:
                decoded_src = cdc.decode(wire, base=self._dest_base(dst_store, unit))
            except codec_lib.StaleBaseError:
                with self._acct_lock:
                    self.delta_stale_fallbacks += 1
                if rec.enabled:
                    rec.counter_add(obs.CTR_DELTA_STALE, 1)
                    if track is not None:
                        rec.event(
                            "delta_stale_fallback",
                            track=track,
                            unit=unit.name,
                            codec=codec,
                        )
                wire = self._fault_truncate(
                    src_replica, cdc.encode(raw_payload, dtype)
                )
                wire_nbytes += wire.nbytes
                decoded_src = cdc.decode(wire)
        else:
            wire = cdc.encode(raw_payload, dtype)
            wire = self._fault_truncate(src_replica, wire)
            wire_nbytes = wire.nbytes
            # decode ONCE (deterministic, and it validates the wire
            # framing); the source's advertised checksum is folded over
            # these decoded bytes, and the copy below models the wire
            # transfer + the destination's decode — so the comparison
            # still runs over two distinct buffers, without paying a
            # second dequantize
            decoded_src = cdc.decode(wire)
        if rec.enabled:
            rec.counter_add(obs.CTR_DECODE, rec.clock() - t0)
            if track is not None:
                rec.event("decode", track=track, unit=unit.name, codec=codec,
                          wire_bytes=wire_nbytes)
        t0 = rec.clock() if rec.enabled else 0.0
        expected = (
            checksum_lib.checksum(decoded_src) if self.verify_checksums else 0
        )
        t_verify = (rec.clock() - t0) if rec.enabled else 0.0
        payload = decoded_src.copy()  # the wire copy, decoded at the dest
        self._fault_flip(src_replica, payload, self.verify_checksums)
        if self.verify_checksums:
            t0 = rec.clock() if rec.enabled else 0.0
            got = checksum_lib.checksum(payload)
            if rec.enabled:
                rec.counter_add(obs.CTR_VERIFY, t_verify + (rec.clock() - t0))
                if track is not None:
                    rec.event("verify", track=track, unit=unit.name)
            if got != expected:
                raise ChecksumError(
                    f"unit {unit.name} ({codec}) from "
                    f"{src_replica}/shard{shard_idx}: decoded checksum "
                    f"{got:#x} != expected {expected:#x}"
                )
        dst_store.write_unit(unit, payload)
        self._account(link_class, wire_nbytes, unit.nbytes)

    def read_unit_range(
        self,
        src_replica: str,
        shard_idx: int,
        unit: TransferUnit,
        offset: int,
        nbytes: int,
        codec: str = "raw",
        link_class: str = "rdma",
        dest_base: Optional[np.ndarray] = None,
        decode: bool = True,
    ) -> np.ndarray:
        """Pull one byte sub-range of a transfer unit (sub-unit chunking,
        and — since the row-grid reshard planner — every resharded
        interval read, which arrives here as a widened unit range).

        There is no manifest checksum at chunk granularity: the source
        checksums the range at read time and the reader re-verifies after
        the wire copy; for a raw codec the caller additionally verifies
        the *assembled* unit against the manifest checksum, so end-to-end
        protection is preserved under chunking.

        ``decode=False`` returns the *wire frame* instead of decoded
        payload bytes (non-raw, non-delta codecs only): the transit
        checksum then runs over the wire bytes and the caller decodes —
        the fused dequant+gather kernel parses frames and writes repacked
        rows directly, skipping the staging decode entirely. Byte
        accounting is identical to the decoding path (wire bytes on the
        wire, ``nbytes`` of payload represented).

        Non-raw codecs encode the chunk independently; the range is in
        *decoded* (payload) space and ``offset`` must sit on a codec row
        boundary (:meth:`~repro.transfer.codec.WireCodec.row_bytes`) so
        the chunk's quantization rows coincide with the whole-unit
        encoding and the reassembled unit is bit-identical to an
        unchunked transfer. The per-chunk checksum runs over the decoded
        bytes, exactly as in :meth:`pull_unit`.

        For a delta codec the caller passes ``dest_base`` — the
        destination's held bytes for this exact chunk range (the
        transport has no destination store on this path). Row alignment
        makes the chunk's base digest well-defined: the held chunk at a
        row boundary is exactly the base-codec round-trip of the source
        snapshot's chunk.

        The swarm served-prefix guard applies at chunk granularity too:
        ``read_unit`` below refuses units past the source's watermark, so
        a chunk of a not-yet-final unit can never be served (chunk-level
        checksums alone would not catch it — they are computed at read
        time and would happily cover garbage)."""
        src = self.registry.get(src_replica, shard_idx)
        self._fault_read(src_replica, shard_idx)
        full = src.read_unit(unit)
        # a zero-length tail chunk (offset == nbytes == end of unit) is a
        # valid no-op read; negative lengths and any byte past the unit
        # end are not
        if nbytes < 0 or offset < 0 or offset + nbytes > full.nbytes:
            raise TensorHubError(
                f"unit {unit.name}: chunk [{offset}, {offset + nbytes}) "
                f"exceeds unit of {full.nbytes}B"
            )
        view = full[offset : offset + nbytes]
        rec = self.recorder
        if codec == "raw":
            t0 = rec.clock() if rec.enabled else 0.0
            expected = checksum_lib.checksum(view) if self.verify_checksums else 0
            t_verify = (rec.clock() - t0) if rec.enabled else 0.0
            payload = view.copy()  # the wire copy
            self._fault_flip(src_replica, payload, self.verify_checksums)
            if self.verify_checksums:
                t0 = rec.clock() if rec.enabled else 0.0
                got = checksum_lib.checksum(payload)
                if rec.enabled:
                    rec.counter_add(obs.CTR_VERIFY, t_verify + (rec.clock() - t0))
                if got != expected:
                    raise ChecksumError(
                        f"chunk {unit.name}[{offset}:{offset + nbytes}] from "
                        f"{src_replica}/shard{shard_idx}: checksum {got:#x} != "
                        f"expected {expected:#x}"
                    )
            self._account(link_class, nbytes, nbytes)
            return payload
        cdc = codec_lib.get_codec(codec)
        dtype = src.unit_dtype(unit)
        rb = cdc.row_bytes(dtype)
        if offset % rb or (nbytes % rb and offset + nbytes != full.nbytes):
            raise codec_lib.CodecError(
                f"chunk {unit.name}[{offset}:{offset + nbytes}] not aligned "
                f"to the {codec} codec's {rb}B row granularity — the "
                "reassembled unit would diverge from an unchunked transfer"
            )
        if not decode:
            if getattr(cdc, "needs_base", False):
                raise codec_lib.CodecError(
                    f"wire-frame reads cannot carry the base-referencing "
                    f"codec {codec!r} (no destination base at frame "
                    "granularity) — resolve the reshard codec first"
                )
            t0 = rec.clock() if rec.enabled else 0.0
            wire = self._fault_truncate(src_replica, cdc.encode(view, dtype))
            if rec.enabled:
                rec.counter_add(obs.CTR_DECODE, rec.clock() - t0)
            t0 = rec.clock() if rec.enabled else 0.0
            expected = (
                checksum_lib.checksum(wire) if self.verify_checksums else 0
            )
            t_verify = (rec.clock() - t0) if rec.enabled else 0.0
            payload = wire.copy()  # the wire copy, decoded by the caller
            self._fault_flip(src_replica, payload, self.verify_checksums)
            if self.verify_checksums:
                t0 = rec.clock() if rec.enabled else 0.0
                got = checksum_lib.checksum(payload)
                if rec.enabled:
                    rec.counter_add(
                        obs.CTR_VERIFY, t_verify + (rec.clock() - t0)
                    )
                if got != expected:
                    raise ChecksumError(
                        f"chunk {unit.name}[{offset}:{offset + nbytes}] "
                        f"({codec} wire) from {src_replica}/shard{shard_idx}: "
                        f"wire checksum {got:#x} != expected {expected:#x}"
                    )
            self._account(link_class, payload.nbytes, nbytes)
            return payload
        t0 = rec.clock() if rec.enabled else 0.0
        if getattr(cdc, "needs_base", False):
            base_full = src.base_unit(unit)
            base_view = (
                None if base_full is None else base_full[offset : offset + nbytes]
            )
            wire = self._fault_truncate(
                src_replica, cdc.encode(view, dtype, base=base_view)
            )
            wire_nbytes = wire.nbytes
            try:
                decoded_src = cdc.decode(wire, base=dest_base)
            except codec_lib.StaleBaseError:
                with self._acct_lock:
                    self.delta_stale_fallbacks += 1
                if rec.enabled:
                    rec.counter_add(obs.CTR_DELTA_STALE, 1)
                wire = self._fault_truncate(src_replica, cdc.encode(view, dtype))
                wire_nbytes += wire.nbytes
                decoded_src = cdc.decode(wire)
        else:
            wire = self._fault_truncate(src_replica, cdc.encode(view, dtype))
            wire_nbytes = wire.nbytes
            # single decode (see pull_unit): checksum the decoded bytes at
            # the source, copy models the wire + destination decode
            decoded_src = cdc.decode(wire)
        if rec.enabled:
            rec.counter_add(obs.CTR_DECODE, rec.clock() - t0)
        t0 = rec.clock() if rec.enabled else 0.0
        expected = (
            checksum_lib.checksum(decoded_src) if self.verify_checksums else 0
        )
        t_verify = (rec.clock() - t0) if rec.enabled else 0.0
        payload = decoded_src.copy()  # the wire copy, decoded at the dest
        self._fault_flip(src_replica, payload, self.verify_checksums)
        if self.verify_checksums:
            t0 = rec.clock() if rec.enabled else 0.0
            got = checksum_lib.checksum(payload)
            if rec.enabled:
                rec.counter_add(obs.CTR_VERIFY, t_verify + (rec.clock() - t0))
            if got != expected:
                raise ChecksumError(
                    f"chunk {unit.name}[{offset}:{offset + nbytes}] ({codec}) "
                    f"from {src_replica}/shard{shard_idx}: decoded checksum "
                    f"{got:#x} != expected {expected:#x}"
                )
        self._account(link_class, wire_nbytes, nbytes)
        return payload

