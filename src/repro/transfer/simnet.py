"""Discrete-event network simulator with max-min fair bandwidth sharing.

The container has one CPU and no RNIC, so the paper's *timing* behaviour is
reproduced with a calibrated fluid-flow model: each transfer is a flow over
a set of unidirectional links (full-duplex NICs are two links); active flows
share every link max-min fairly (progressive filling), which naturally
produces the contention effects the paper measures — e.g. the quadratic
stall growth of single-rooted fan-out in Fig 7b vs the linear growth with
pipeline replication.

The *control plane* driven on top of this simulator is the real
``ReferenceServer`` — identical code to the threaded client path.

Processes are Python generators that yield:

* ``env.timeout(dt)``   — resume after dt seconds of virtual time
* ``SimEvent``          — resume when the event fires (``ev.succeed()``)
* ``network.flow(...)`` — resume when the flow completes (raises
  ``FlowKilled`` into the generator if a link endpoint died)

Determinism: the event heap is ordered by (time, seq); no wall-clock or
randomness enters unless a benchmark injects a seeded RNG.
"""

from __future__ import annotations

import heapq
import itertools
import math
from typing import Callable, Dict, Generator, Iterable, List, Optional, Tuple

Process = Generator


class FlowKilled(Exception):
    """The flow's src/dst vanished; delivered after the detection delay.

    ``transient`` marks an injected gray failure (flaky read): the
    endpoint is still alive and a retry is expected to succeed, so the
    reader backs off and re-issues instead of reporting a dead source.
    """

    def __init__(self, tag: str = "", transient: bool = False) -> None:
        super().__init__(tag)
        self.tag = tag
        self.transient = transient


class SimEvent:
    """One-shot event; processes may wait on it, it may carry a value."""

    __slots__ = ("env", "_done", "_value", "_error", "_waiters", "_callbacks")

    def __init__(self, env: "SimEnv") -> None:
        self.env = env
        self._done = False
        self._value = None
        self._error: Optional[BaseException] = None
        self._waiters: List[Process] = []
        self._callbacks: List[Callable[["SimEvent"], None]] = []

    @property
    def triggered(self) -> bool:
        return self._done

    @property
    def value(self):
        return self._value

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def add_callback(self, cb: Callable[["SimEvent"], None]) -> None:
        if self._done:
            self.env.schedule(0.0, lambda: cb(self))
        else:
            self._callbacks.append(cb)

    def succeed(self, value=None) -> None:
        if self._done:
            return
        self._done = True
        self._value = value
        for p in self._waiters:
            self.env._resume(p, value=value)
        self._waiters.clear()
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()

    def fail(self, error: BaseException) -> None:
        if self._done:
            return
        self._done = True
        self._error = error
        for p in self._waiters:
            self.env._resume(p, error=error)
        self._waiters.clear()
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()


class SimEnv:
    """Minimal deterministic event loop (SimPy-flavoured)."""

    #: safety-net period for keyed waiters (seconds of virtual time). A
    #: keyed wait is woken spuriously after this long *only once the hard
    #: event heap has quiesced* — i.e. a missed wakeup can delay a waiter,
    #: never deadlock it, and the tick costs nothing on healthy runs
    #: (it fires after all real work, when no waiter is left pending).
    safety_tick: float = 30.0

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        #: broadcast event for "server state changed" waiters; re-armed on
        #: every notify (condition-variable analogue)
        self._state_event = SimEvent(self)
        #: keyed one-shot events for targeted wakeups (e.g. per-source
        #: progress-counter advances) — avoids thundering-herd wake storms
        self._keyed: Dict[object, SimEvent] = {}
        #: next armed safety tick (None = nothing armed). Kept *out* of the
        #: hard heap so an armed-but-unneeded tick never advances ``now``.
        self._safety_at: Optional[float] = None

    # -- scheduling --------------------------------------------------------------

    def schedule(self, delay: float, cb: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self.now + max(delay, 0.0), next(self._seq), cb))

    def timeout(self, delay: float) -> SimEvent:
        ev = SimEvent(self)
        self.schedule(delay, ev.succeed)
        return ev

    def state_wait(self) -> SimEvent:
        """Wait until the next state_notify() (server watcher bump)."""
        return self._state_event

    def state_notify(self) -> None:
        ev = self._state_event
        self._state_event = SimEvent(self)
        ev.succeed()

    def key_wait(self, key: object) -> SimEvent:
        """Wait until the next key_notify(key) (or a safety tick)."""
        ev = self._keyed.get(key)
        if ev is None:
            ev = SimEvent(self)
            self._keyed[key] = ev
        if self._safety_at is None:
            self._safety_at = self.now + self.safety_tick
        return ev

    def key_notify(self, key: object) -> None:
        ev = self._keyed.pop(key, None)
        if ev is not None:
            ev.succeed()

    def key_notify_where(self, pred: Callable[[object], bool]) -> int:
        """Fire every pending keyed event whose key matches ``pred`` —
        used by failure paths that cannot enumerate exact keys (e.g. all
        progress keys of a dying replica, whatever its shard count)."""
        hits = [k for k in self._keyed if pred(k)]
        for k in hits:
            self._keyed.pop(k).succeed()
        return len(hits)

    def _fire_safety(self) -> None:
        """Spurious-wakeup sweep: fire and drop every keyed event. Waiters
        re-check their condition and re-wait (re-arming the tick); stale
        entries nobody listens to are garbage-collected here."""
        stale = self._keyed
        self._keyed = {}
        for ev in stale.values():
            ev.succeed()

    def any_of(self, *events: SimEvent) -> SimEvent:
        """Combined event that fires when the first constituent fires."""
        out = SimEvent(self)
        for ev in events:
            ev.add_callback(
                lambda e: out.fail(e.error) if e.error is not None else out.succeed(e.value)
            )
        return out

    # -- processes ----------------------------------------------------------------

    def process(self, gen: Process) -> SimEvent:
        """Start a generator process; returns an event that fires with the
        generator's return value (or error)."""
        done = SimEvent(self)
        self.schedule(0.0, lambda: self._step(gen, done, None, None))
        return done

    def _resume(self, gen_ctx, value=None, error: Optional[BaseException] = None) -> None:
        gen, done = gen_ctx
        self.schedule(0.0, lambda: self._step(gen, done, value, error))

    def _step(self, gen: Process, done: SimEvent, value, error) -> None:
        try:
            if error is not None:
                yielded = gen.throw(error)
            else:
                yielded = gen.send(value)
        except StopIteration as stop:
            done.succeed(stop.value)
            return
        except BaseException as exc:  # propagate process crash to waiters
            done.fail(exc)
            return
        if isinstance(yielded, SimEvent):
            if yielded.triggered:
                if yielded._error is not None:
                    self._resume((gen, done), error=yielded._error)
                else:
                    self._resume((gen, done), value=yielded._value)
            else:
                yielded._waiters.append((gen, done))
        else:
            raise TypeError(f"process yielded {yielded!r}; expected SimEvent")

    # -- run ----------------------------------------------------------------------

    def run(self, until: float = math.inf) -> float:
        while True:
            while self._heap and self._heap[0][0] <= until:
                t, _, cb = heapq.heappop(self._heap)
                self.now = max(self.now, t)
                cb()
            # Hard heap quiesced: if keyed waiters are still pending, fire
            # the safety net and keep going; otherwise we are done. The
            # tick advances virtual time only in this (otherwise-deadlocked)
            # case, so healthy runs see identical timings.
            if (
                self._safety_at is not None
                and self._safety_at <= until
                and any(
                    ev._waiters or ev._callbacks for ev in self._keyed.values()
                )
            ):
                self.now = max(self.now, self._safety_at)
                self._safety_at = None
                self._fire_safety()
                continue
            break
        # disarm only when nobody is left waiting: a keyed waiter pending
        # across a finite-`until` boundary keeps its safety net for the
        # next run() call (clearing it unconditionally would deadlock a
        # missed wakeup, which the safety tick exists to prevent)
        if not any(ev._waiters or ev._callbacks for ev in self._keyed.values()):
            self._safety_at = None
        if math.isfinite(until):
            self.now = max(self.now, until)
        return self.now


# ---------------------------------------------------------------------------
# Fluid-flow network
# ---------------------------------------------------------------------------


class Link:
    """Unidirectional capacity (bytes/s). A full-duplex NIC is two links."""

    __slots__ = ("name", "capacity", "flows")

    def __init__(self, name: str, capacity: float) -> None:
        self.name = name
        self.capacity = capacity
        # insertion-ordered set: Flow hashes by id, so a real set would
        # iterate in a different order every process/run — float
        # accumulation order must be reproducible for bit-identical replay
        self.flows: Dict["Flow", None] = {}

    def __repr__(self) -> str:
        return f"Link({self.name}, {self.capacity/1e9:.1f} GB/s, {len(self.flows)} flows)"


class Flow:
    __slots__ = (
        "nbytes", "links", "rate_cap", "remaining", "rate", "event", "dead", "tag",
    )

    def __init__(
        self,
        nbytes: float,
        links: Tuple[Link, ...],
        rate_cap: float,
        event: SimEvent,
        tag: str = "",
    ) -> None:
        self.nbytes = nbytes
        self.links = links
        self.rate_cap = rate_cap
        self.remaining = float(nbytes)
        self.rate = 0.0
        self.event = event
        self.dead = False
        self.tag = tag


class SimNetwork:
    """Flows over links with max-min fair sharing, on a SimEnv."""

    def __init__(self, env: SimEnv) -> None:
        self.env = env
        self._links: Dict[str, Link] = {}
        #: insertion-ordered (see Link.flows): deterministic iteration
        self._flows: Dict[Flow, None] = {}
        self._last_advance = 0.0
        #: earliest pending completion tick (de-dup: re-scheduling on every
        #: reallocation without it turns interacting windowed flows into a
        #: stale-tick storm, each tick a global O(flows) reallocation)
        self._next_tick = math.inf
        self.bytes_delivered = 0.0
        #: per-link cumulative bytes (for traffic accounting, Fig 12c)
        self.link_bytes: Dict[str, float] = {}

    # -- topology -------------------------------------------------------------------

    def link(self, name: str, capacity: Optional[float] = None) -> Link:
        lk = self._links.get(name)
        if lk is None:
            if capacity is None:
                raise KeyError(f"unknown link {name}")
            lk = Link(name, capacity)
            self._links[name] = lk
            self.link_bytes[name] = 0.0
        elif capacity is not None and lk.capacity != capacity:
            raise ValueError(f"link {name} redefined with different capacity")
        return lk

    # -- flows ------------------------------------------------------------------------

    def flow(
        self,
        nbytes: float,
        links: Iterable[Link],
        *,
        rate_cap: float = math.inf,
        latency: float = 0.0,
        tag: str = "",
    ) -> SimEvent:
        """Start a flow; returns its completion event. ``latency`` models the
        fixed per-message setup cost (registration, rendezvous, headers)."""
        ev = SimEvent(self.env)
        fl = Flow(nbytes, tuple(links), rate_cap, ev, tag)
        if nbytes <= 0:
            self.env.schedule(latency, ev.succeed)
            return ev

        def start() -> None:
            if fl.dead:
                return
            self._advance_to_now()
            self._flows[fl] = None
            for lk in fl.links:
                lk.flows[fl] = None
            self._reallocate()

        self.env.schedule(latency, start)
        return ev

    def kill_flows(
        self,
        pred: Callable[[Flow], bool],
        *,
        notice_delay: float = 0.0,
        transient: bool = False,
    ) -> int:
        """Abort flows matching pred; waiters get FlowKilled after
        notice_delay (the reader-side failure-detection timeout, 5.1.3).
        ``transient`` flags the kill as a retryable gray fault rather
        than a dead endpoint."""
        victims = [f for f in self._flows if pred(f)]
        self._advance_to_now()
        for fl in victims:
            self._detach(fl)
            fl.dead = True
            self.env.schedule(
                notice_delay,
                (lambda f=fl: f.event.fail(FlowKilled(f.tag, transient=transient))),
            )
        if victims:
            self._reallocate()
        return len(victims)

    # -- fluid model ---------------------------------------------------------------------

    def _detach(self, fl: Flow) -> None:
        self._flows.pop(fl, None)
        for lk in fl.links:
            lk.flows.pop(fl, None)

    def _advance_to_now(self) -> bool:
        """Credit every active flow with rate * elapsed. Returns True when
        any flow finished (the flow set — and hence the rate allocation —
        changed)."""
        dt = self.env.now - self._last_advance
        self._last_advance = self.env.now
        if dt <= 0:
            return False
        finished: List[Flow] = []
        for fl in self._flows:
            moved = min(fl.remaining, fl.rate * dt)
            fl.remaining -= moved
            self.bytes_delivered += moved
            for lk in fl.links:
                self.link_bytes[lk.name] += moved
            # relative epsilon: float rounding can strand sub-byte residues
            # whose completion time underflows now+dt (dt ~ 1e-17 s), which
            # would spin the event loop forever
            if fl.remaining <= max(1e-6, fl.nbytes * 1e-9):
                finished.append(fl)
        for fl in finished:
            self._detach(fl)
            fl.event.succeed()
        return bool(finished)

    def _reallocate(self) -> None:
        """Max-min fair (progressive filling) over all active flows."""
        flows = list(self._flows)
        if not flows:
            return
        unfixed: Dict[Flow, None] = dict.fromkeys(flows)
        cap: Dict[Link, float] = {}
        for fl in flows:
            for lk in fl.links:
                cap.setdefault(lk, lk.capacity)
        for fl in flows:
            fl.rate = 0.0
        while unfixed:
            # bottleneck link: min fair share among links carrying unfixed flows
            best_share = math.inf
            for lk, c in cap.items():
                n = sum(1 for f in lk.flows if f in unfixed)
                if n:
                    best_share = min(best_share, c / n)
            # flows individually capped below the share are fixed at cap
            capped = [f for f in unfixed if f.rate_cap <= best_share]
            if capped:
                for f in capped:
                    f.rate = f.rate_cap
                    unfixed.pop(f, None)
                    for lk in f.links:
                        cap[lk] = max(cap[lk] - f.rate_cap, 0.0)
                continue
            if not math.isfinite(best_share):
                break
            # fix all flows crossing the bottleneck link(s)
            for lk, c in list(cap.items()):
                n = sum(1 for f in lk.flows if f in unfixed)
                if n and abs(c / n - best_share) < 1e-12:
                    for f in [f for f in lk.flows if f in unfixed]:
                        f.rate = best_share
                        unfixed.pop(f, None)
                        for l2 in f.links:
                            cap[l2] = max(cap[l2] - best_share, 0.0)
        self._schedule_next_completion()

    def _schedule_next_completion(self) -> None:
        # Schedule a tick at the earliest completion under *current* rates,
        # but only when it beats the earliest tick already pending: a
        # pending earlier tick re-evaluates anyway, so the true earliest
        # completion stays covered without flooding the heap. Stale ticks
        # (rates changed since) advance the fluid model; they trigger the
        # global reallocation only when a flow actually finished.
        nxt = math.inf
        for fl in self._flows:
            if fl.rate > 0:
                nxt = min(nxt, fl.remaining / fl.rate)
        if not math.isfinite(nxt):
            return
        at = self.env.now + nxt
        if at >= self._next_tick - 1e-15:
            return
        self._next_tick = at

        def tick() -> None:
            if self._next_tick <= self.env.now:
                self._next_tick = math.inf
            if self._advance_to_now():
                self._reallocate()
            else:
                self._schedule_next_completion()

        self.env.schedule(nxt, tick)
