"""Transfer engine package.

Imports are lazy to break the ``repro.core`` <-> ``repro.transfer`` cycle
(the client library lives in core and uses the engine; the engine raises
core error types).
"""

__all__ = [
    "LocalTransport",
    "TransportError",
    "WorkerRegistry",
    "WorkerStore",
    "fold_checksum",
]


def __getattr__(name):
    if name == "fold_checksum":
        from repro.transfer.checksum import checksum

        return checksum
    if name in ("LocalTransport", "TransportError", "WorkerRegistry", "WorkerStore"):
        from repro.transfer import engine

        return getattr(engine, name)
    raise AttributeError(name)
