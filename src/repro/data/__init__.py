from repro.data.synthetic import BigramStream, PromptSet, audio_batch

__all__ = ["BigramStream", "PromptSet", "audio_batch"]
