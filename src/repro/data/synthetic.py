"""Synthetic, deterministic data pipelines.

``BigramStream`` draws token sequences from a seeded random bigram chain so
a model can actually reduce loss on it (examples train against it);
``prompts`` produces the RL prompt batches. Everything is seeded and
restartable from an offset — the trainer checkpoint records the offset so a
restarted trainer resumes the exact stream (checkpoint/restart story).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class BigramStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 4  # successors per token (lower = easier to learn)
    offset: int = 0  # batches already consumed (checkpoint/restore)

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        self._table = rng.integers(0, self.vocab, size=(self.vocab, self.branching))

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + self.offset)
        self.offset += 1
        toks = np.empty((self.batch, self.seq_len), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        choices = rng.integers(0, self.branching, size=(self.batch, self.seq_len))
        for t in range(1, self.seq_len):
            toks[:, t] = self._table[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


@dataclasses.dataclass
class PromptSet:
    """RL prompts: short prefixes; the (rule-based) reward scores how well a
    response continues the bigram chain — a stand-in for the paper's
    rule-based rewards (2.1, step 2)."""

    vocab: int
    prompt_len: int
    seed: int = 0
    branching: int = 4

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        self._table = rng.integers(0, self.vocab, size=(self.vocab, self.branching))

    def sample(self, n: int, step: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 7 + step)
        toks = np.empty((n, self.prompt_len), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=n)
        choices = rng.integers(0, self.branching, size=(n, self.prompt_len))
        for t in range(1, self.prompt_len):
            toks[:, t] = self._table[toks[:, t - 1], choices[:, t]]
        return toks

    def reward(self, sequences: np.ndarray, prompt_len: int) -> np.ndarray:
        """Fraction of response transitions that are valid chain steps."""
        resp = sequences[:, prompt_len - 1 :]
        valid = np.zeros(sequences.shape[0], dtype=np.float64)
        steps = resp.shape[1] - 1
        for t in range(steps):
            succ = self._table[resp[:, t]]  # [B, branching]
            valid += (succ == resp[:, t + 1][:, None]).any(axis=1)
        return (valid / max(steps, 1)).astype(np.float32)


def audio_batch(
    batch: int, seq: int, frame_dim: int, vocab: int, seed: int
) -> Dict[str, np.ndarray]:
    """Synthetic masked-prediction batch for the audio encoder."""
    rng = np.random.default_rng(seed)
    return {
        "frames": rng.standard_normal((batch, seq, frame_dim)).astype(np.float32),
        "targets": rng.integers(0, vocab, size=(batch, seq)).astype(np.int32),
        "mask": (rng.random((batch, seq)) < 0.08),
    }
